//! Real-thread wall-clock executor — the paper's claim on actual cores.
//!
//! The m nodes are dealt round-robin onto `workers` OS threads. Each
//! worker owns its nodes' `(ū, v̄)` state, its own θ-table, RNG streams
//! and oracle; gradients travel through the shared freshest-wins
//! [`MailboxGrid`] (one slot per directed edge — the concurrent
//! analogue of the simulator's keep-freshest mailbox).
//!
//! * **A²DWB / A²DWBN** run barrier-free: a worker claims the next
//!   global iteration index from an atomic counter, activates, publishes
//!   and immediately moves on — no thread ever waits for another, which
//!   is precisely the waiting overhead the paper removes.
//! * **DCWB** runs with a [`std::sync::Barrier`] per round phase
//!   (compute/publish, then collect/update), so every round is paced by
//!   the slowest worker — the synchronous baseline's cost, now made of
//!   real wall-clock waiting instead of simulated delay maxima.
//!
//! Both modes execute the same **iteration budget** the simulator would
//! issue in `duration` virtual seconds (`⌈duration/interval⌉` sweeps of
//! m activations), so async-vs-sync comparisons are at equal work, and
//! wall-clock differences isolate coordination overhead.
//!
//! Heterogeneity: `compute_time > 0` makes every activation cost that
//! many real seconds (in expectation) of `thread::sleep`, scaled by the
//! node's [`FaultModel`](crate::coordinator::FaultModel) straggler
//! factor and a deterministic per-activation jitter in [0.5, 1.5) —
//! real stragglers and real compute variance on real threads, the
//! scenario axis the simulator can only approximate. The jitter is what
//! the barrier pays for: at an equal iteration budget the synchronous
//! baseline's wall time is `Σ_rounds max_workers(round work)` while the
//! asynchronous executors pay only `max_workers Σ_rounds(round work)`,
//! and the gap between those two is exactly the paper's waiting
//! overhead.
//!
//! Metrics: sampling is paced by [`SampleCadence`]. Under the default
//! wall-clock cadence the spawning thread snapshots per-node dual
//! iterates every few milliseconds; under
//! [`SampleCadence::Activations`] the worker that completes every k-th
//! activation takes the snapshot synchronously (dense and — at
//! `workers = 1` — fully deterministic) and the spawning thread drains
//! and evaluates the queued snapshots. Either way the same
//! common-random-number metrics as the simulator are evaluated; the
//! virtual-equivalent timestamp of a sample is `activations/m ·
//! interval` so threaded and simulated curves share an x-axis, and
//! `dual_wall` carries the honest wall-clock axis.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::{Duration, Instant};

use super::transport::{MailboxGrid, ThreadedTransport};
use super::{activate_node, initial_exchange, SampleCadence, StepCtx};
use crate::algo::wbp::WbpNode;
use crate::algo::{AlgorithmKind, ThetaSeq};
use crate::coordinator::session::{RunCtl, RunEvent, RunTotals};
use crate::coordinator::{CancelToken, ExperimentConfig, MetricsEvaluator};
use crate::graph::Graph;
use crate::measures::{NodeMeasure, Samples};
use crate::rng::Rng64;

/// Read-only run context shared by every worker thread.
#[derive(Clone, Copy)]
struct Shared<'a> {
    cfg: &'a ExperimentConfig,
    graph: &'a Graph,
    measures: &'a [Box<dyn NodeMeasure>],
    grid: &'a MailboxGrid,
    eta_snaps: &'a [Mutex<Vec<f64>>],
    /// (activations, wall seconds, stacked η̄) snapshots queued by
    /// workers under [`SampleCadence::Activations`]; drained and
    /// evaluated by the spawning thread.
    snap_queue: &'a Mutex<Vec<(u64, f64, Vec<f64>)>>,
    /// Snapshot-count cap derived from [`SNAP_QUEUE_BYTES`] and the
    /// instance size m·n.
    snap_cap: usize,
    /// Snapshots shed past the cap (reported after the run).
    snap_dropped: &'a AtomicU64,
    /// Run start — workers stamp snapshots against it so `dual_wall`
    /// carries capture time, not evaluation time.
    t0: Instant,
    k_counter: &'a AtomicUsize,
    progress: &'a AtomicU64,
    /// Cooperative early-stop flag (the session's
    /// [`CancelToken`]): workers poll it at activation/round
    /// granularity and wind down through the normal join path.
    cancel: &'a CancelToken,
    barrier: &'a Barrier,
    node_factors: &'a [f64],
    gamma: f64,
    m_theta: usize,
    sweeps: usize,
    sync: bool,
    compensated: bool,
}

/// Memory-safety valve for the activation-paced snapshot queue: when
/// the evaluating thread falls behind by this many **bytes** of queued
/// snapshots (each m·n f64), workers shed further ones (counted and
/// reported) instead of ballooning RSS — never reached at test scales,
/// only by `Activations(small k)` × huge-budget runs. Sized in bytes so
/// paper-scale instances (m=500, n=784 ⇒ ~3 MB per snapshot) stay
/// bounded at the same memory as tiny ones.
const SNAP_QUEUE_BYTES: usize = 256 << 20;

/// Count one finished activation; under activation-paced sampling the
/// worker crossing a multiple of k snapshots the whole network state
/// (its own node's fresh η̄ is already in `eta_snaps`).
fn bump_progress(sh: &Shared<'_>, n: usize) {
    let acts = sh.progress.fetch_add(1, Ordering::Relaxed) + 1;
    if let SampleCadence::Activations(k) = sh.cfg.sample_cadence {
        if acts % k == 0 {
            // cheap early check so shedding skips the m·n capture cost
            // entirely in the overload regime…
            if sh.snap_queue.lock().unwrap().len() >= sh.snap_cap {
                sh.snap_dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            let m = sh.cfg.nodes;
            let mut snap = vec![0.0; m * n];
            for (j, slot) in sh.eta_snaps.iter().enumerate() {
                snap[j * n..(j + 1) * n].copy_from_slice(&slot.lock().unwrap());
            }
            let wall = sh.t0.elapsed().as_secs_f64();
            // …and a re-check under the push lock keeps the cap exact
            // when several workers race past the early check at once.
            let mut queue = sh.snap_queue.lock().unwrap();
            if queue.len() >= sh.snap_cap {
                drop(queue);
                sh.snap_dropped.fetch_add(1, Ordering::Relaxed);
            } else {
                queue.push((acts, wall, snap));
            }
        }
    }
}

/// Simulated compute cost of one activation — delegates to the
/// backend-shared [`super::sleep_compute`] (one jitter/straggler
/// formula for the threaded and sharded executors).
fn sleep_compute(sh: &Shared<'_>, i: usize, jitter: &mut Rng64) {
    super::sleep_compute(sh.cfg.compute_time, sh.node_factors[i], jitter);
}

/// Ledger of this worker's progress through the DCWB barrier
/// protocol: every wait goes through [`SyncPacer::wait`], so on any
/// early exit — an error return or a panic caught by [`worker_loop`]
/// — [`SyncPacer::drain`] can stand in for the remaining phases and
/// no peer is ever stranded at a [`Barrier::wait`] (std barriers have
/// no poisoning). Async runs have `total = 0` and drain is a no-op.
struct SyncPacer<'a> {
    barrier: &'a Barrier,
    /// Waits this worker owes over the whole run (2 per DCWB round).
    total: usize,
    waited: std::cell::Cell<usize>,
}

impl<'a> SyncPacer<'a> {
    fn new(barrier: &'a Barrier, total: usize) -> Self {
        Self { barrier, total, waited: std::cell::Cell::new(0) }
    }

    fn wait(&self) {
        self.waited.set(self.waited.get() + 1);
        self.barrier.wait();
    }

    /// Serve every remaining barrier phase without doing any work.
    fn drain(&self) {
        while self.waited.get() < self.total {
            self.wait();
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// One worker thread: runs [`worker_body`] with panic containment.
/// Whatever goes wrong — an error return (oracle build failure) or a
/// panic anywhere in the activation path — the worker first honors
/// every barrier phase it still owes its DCWB peers, then reports the
/// failure; the monitor loop sees every handle finish and `run`
/// returns the error instead of spinning on a wedged barrier forever.
fn worker_loop(
    sh: Shared<'_>,
    worker_id: usize,
    mine: Vec<(usize, WbpNode, Rng64)>,
) -> Result<(Vec<(usize, WbpNode)>, u64, usize), String> {
    let pacer =
        SyncPacer::new(sh.barrier, if sh.sync { 2 * sh.sweeps } else { 0 });
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        worker_body(&sh, worker_id, mine, &pacer)
    }))
    .unwrap_or_else(|payload| {
        Err(format!("worker {worker_id} panicked: {}", panic_message(payload.as_ref())))
    });
    if out.is_err() {
        pacer.drain();
    }
    out
}

/// The worker's actual run. Returns its nodes (for the final metric
/// snapshot), the number of messages it published, and how many sweeps
/// it completed (shorter than the budget only under cancellation). All
/// barrier traffic goes through `pacer` so [`worker_loop`] (or the
/// cancellation path, which drains the remaining DCWB phases exactly
/// like a failed worker would) can settle the protocol on early exit.
fn worker_body(
    sh: &Shared<'_>,
    worker_id: usize,
    mut mine: Vec<(usize, WbpNode, Rng64)>,
    pacer: &SyncPacer<'_>,
) -> Result<(Vec<(usize, WbpNode)>, u64, usize), String> {
    let n = sh.cfg.support_size();
    let mut oracle = sh
        .cfg
        .backend
        .build(sh.cfg.samples_per_activation, n)
        .map_err(|e| format!("worker {worker_id}: oracle build failed: {e}"))?;
    let mut theta = ThetaSeq::new(sh.m_theta);
    let mut samples = Samples::empty();
    let mut point = vec![0.0; n];
    let mut transport = ThreadedTransport::new(sh.grid);
    let mut jitter = Rng64::new(sh.cfg.seed ^ 0x4A54_5452 ^ worker_id as u64);
    let ctx = StepCtx {
        beta: sh.cfg.beta,
        gamma: sh.gamma,
        batch: sh.cfg.samples_per_activation,
        m_theta: sh.m_theta,
        diag: sh.cfg.diag,
    };

    let mut sweeps_done = 0usize;
    if sh.sync {
        // DCWB: two barriers per round — broadcasts of round r+1 must
        // not overtake a slow neighbor still collecting round r.
        for r in 0..sh.sweeps {
            if sh.cancel.is_cancelled() {
                // settle the remaining barrier phases (peers may notice
                // the flag a round later — drain keeps them paced, the
                // exact mechanism a failed worker uses)
                pacer.drain();
                break;
            }
            for (i, node, rng) in mine.iter_mut() {
                let i = *i;
                sleep_compute(sh, i, &mut jitter);
                node.eval_point(&mut theta, r, true, &mut point);
                sh.measures[i].draw_samples_into(rng, ctx.batch, &mut samples);
                let rows = sh.measures[i].cost_rows(&samples);
                oracle.eval(&point, &rows, ctx.beta, &mut node.own_grad);
                transport.broadcast(
                    i,
                    r as u64 + 1,
                    std::sync::Arc::new(node.own_grad.clone()),
                );
            }
            pacer.wait();
            for (i, node, _) in mine.iter_mut() {
                let i = *i;
                transport.collect(i, node);
                node.apply_update(
                    &mut theta,
                    r,
                    ctx.m_theta,
                    ctx.gamma,
                    sh.graph.degree(i),
                    ctx.diag,
                );
                node.eta(&mut theta, r + 1, &mut point);
                sh.eta_snaps[i].lock().unwrap().copy_from_slice(&point);
                bump_progress(sh, n);
            }
            pacer.wait();
            sweeps_done = r + 1;
        }
    } else {
        // A²DWB / A²DWBN: barrier-free. Claim a global iteration index,
        // activate, publish, move on.
        'sweeps: for sweep in 0..sh.sweeps {
            for (i, node, rng) in mine.iter_mut() {
                if sh.cancel.is_cancelled() {
                    break 'sweeps;
                }
                let i = *i;
                let k = sh.k_counter.fetch_add(1, Ordering::Relaxed);
                sleep_compute(sh, i, &mut jitter);
                activate_node(
                    node,
                    i,
                    k,
                    sh.compensated,
                    &mut theta,
                    &ctx,
                    sh.graph.degree(i),
                    sh.measures[i].as_ref(),
                    rng,
                    &mut samples,
                    &mut point,
                    oracle.as_mut(),
                    &mut transport,
                );
                node.eta(&mut theta, k + 1, &mut point);
                sh.eta_snaps[i].lock().unwrap().copy_from_slice(&point);
                bump_progress(sh, n);
            }
            sweeps_done = sweep + 1;
        }
    }

    Ok((
        mine.into_iter().map(|(i, node, _)| (i, node)).collect(),
        transport.messages,
        sweeps_done,
    ))
}

/// Run one experiment on the threaded executor, streaming progress
/// through `ctl` (metric samples from the monitor thread, a terminal
/// [`RunEvent::Finished`]) and honoring its cancel flag.
pub(crate) fn run(
    cfg: &ExperimentConfig,
    graph: &Graph,
    workers: usize,
    ctl: &mut RunCtl<'_>,
) -> Result<(), String> {
    let m = cfg.nodes;
    let n = cfg.support_size();
    if workers == 0 {
        return Err("threads executor needs workers >= 1".into());
    }
    if cfg.faults.drop_prob > 0.0 {
        // The mailbox grid delivers every publish; only the simulator
        // has a message-fate model. Refuse rather than silently run a
        // lossless experiment labeled as a lossy one.
        return Err(
            "drop_prob > 0 is modeled by the sim executor only; the threads \
             executor has no message-loss model (straggler factors apply)"
                .into(),
        );
    }
    let workers = workers.min(m);
    let measures = cfg.measure.build_network(m, cfg.seed);
    // Prevalidate the oracle backend here so worker threads cannot fail
    // after the barrier topology is committed.
    let mut init_oracle = cfg.backend.build(cfg.samples_per_activation, n)?;
    let lambda_max = graph.lambda_max();
    let gamma = cfg.gamma_scale / (lambda_max / cfg.beta);

    let sync = cfg.algorithm == AlgorithmKind::Dcwb;
    let compensated = cfg.algorithm != AlgorithmKind::A2dwbn;
    let m_theta = if sync { 1 } else { m };
    // Equal iteration budget: what the simulator issues in `duration`
    // virtual seconds at the §3.3 activation cadence.
    let sweeps = ((cfg.duration / cfg.activation_interval).round() as usize).max(1);
    let budget = sweeps * m;

    let mut nodes: Vec<WbpNode> =
        (0..m).map(|i| WbpNode::new(n, graph.degree(i))).collect();
    let mut root = Rng64::new(cfg.seed ^ 0x5254_4E44);
    let mut node_rngs: Vec<Rng64> = (0..m).map(|i| root.split(i as u64)).collect();
    let node_factors = cfg.faults.node_factors(m, cfg.seed);

    let grid = MailboxGrid::new(graph, n);
    let mut samples = Samples::empty();
    let mut point = vec![0.0; n];
    let mut messages: u64 = 0;

    if !sync {
        // Algorithm 3 line 1. (DCWB has no initial exchange: its first
        // round computes and delivers fresh gradients behind a barrier,
        // exactly like the simulated baseline.)
        let mut theta0 = ThetaSeq::new(m_theta);
        let mut transport = ThreadedTransport::new(&grid);
        initial_exchange(
            &mut nodes,
            &mut theta0,
            &measures,
            &mut node_rngs,
            init_oracle.as_mut(),
            &mut samples,
            cfg.samples_per_activation,
            &mut point,
            cfg.beta,
            &mut transport,
        );
        messages += transport.messages;
    }

    // Deal nodes round-robin onto workers.
    let mut per_worker: Vec<Vec<(usize, WbpNode, Rng64)>> =
        (0..workers).map(|_| Vec::new()).collect();
    for (i, (node, rng)) in nodes.into_iter().zip(node_rngs).enumerate() {
        per_worker[i % workers].push((i, node, rng));
    }

    let k_counter = AtomicUsize::new(0);
    let progress = AtomicU64::new(0);
    let barrier = Barrier::new(workers);
    let eta_snaps: Vec<Mutex<Vec<f64>>> =
        (0..m).map(|_| Mutex::new(vec![0.0; n])).collect();
    let snap_queue: Mutex<Vec<(u64, f64, Vec<f64>)>> = Mutex::new(Vec::new());
    let snap_dropped = AtomicU64::new(0);
    let cancel_token = ctl.token();

    let mut evaluator =
        MetricsEvaluator::new(graph, &measures, cfg.beta, cfg.eval_samples, cfg.seed);
    let mut etas = vec![0.0; m * n];

    // t = 0 sample: the zero state, same value the simulator reports.
    {
        let (dual, consensus, spread) = evaluator.evaluate(&etas, &measures);
        ctl.sample(0.0, 0.0, dual, consensus, spread, 0, 0);
    }

    // The wall clock starts after metric setup and the t=0 evaluation —
    // dual_wall must measure experiment runtime, not evaluator
    // construction (which at paper scale does a full m-node oracle pass).
    let wall_t0 = Instant::now();
    let shared = Shared {
        cfg,
        graph,
        measures: &measures,
        grid: &grid,
        eta_snaps: &eta_snaps,
        snap_queue: &snap_queue,
        snap_cap: (SNAP_QUEUE_BYTES / (m * n * 8)).max(16),
        snap_dropped: &snap_dropped,
        t0: wall_t0,
        k_counter: &k_counter,
        progress: &progress,
        cancel: &cancel_token,
        barrier: &barrier,
        node_factors: &node_factors,
        gamma,
        m_theta,
        sweeps,
        sync,
        compensated,
    };

    let mut nodes_back: Vec<Option<WbpNode>> = (0..m).map(|_| None).collect();

    // Drain and evaluate worker-queued activation-paced snapshots.
    // Each batch is sorted by activation count, and snapshots at or
    // below the last evaluated count are dropped: with several workers
    // a straggler can queue a lower-acts snapshot after a higher one
    // was already evaluated (cross-batch inversion sorting cannot fix),
    // and appending that older network state as a later point would
    // fake a regression blip. Surviving acts are strictly increasing,
    // so the virtual-time axis is monotone by construction; capture
    // walls can still interleave slightly, hence the `last_wall` clamp.
    // `dual_wall` uses the worker-side capture time, not the (possibly
    // much later) evaluation time.
    let rounds_of = |acts: u64| if sync { acts / m as u64 } else { 0 };
    let drain_snaps = |evaluator: &mut MetricsEvaluator,
                       ctl: &mut RunCtl<'_>,
                       last_acts: &mut u64,
                       last_wall: &mut f64| {
        let mut batch = std::mem::take(&mut *snap_queue.lock().unwrap());
        batch.sort_by_key(|&(acts, _, _)| acts);
        for (acts, wall, snap) in batch {
            if acts <= *last_acts {
                continue; // stale straggler snapshot
            }
            *last_acts = acts;
            let (dual, consensus, spread) = evaluator.evaluate(&snap, &measures);
            let t_equiv =
                (acts as f64 / m as f64 * cfg.activation_interval).min(cfg.duration);
            let wall = wall.max(*last_wall);
            *last_wall = wall;
            ctl.sample(t_equiv, wall, dual, consensus, spread, acts, rounds_of(acts));
        }
    };
    let mut cadence_last_acts = 0u64;
    let mut cadence_last_wall = 0.0f64;
    let mut sweeps_done_min = sweeps;

    std::thread::scope(|s| -> Result<(), String> {
        let mut handles = Vec::with_capacity(workers);
        for (w, mine) in per_worker.into_iter().enumerate() {
            handles.push(s.spawn(move || worker_loop(shared, w, mine)));
        }

        // Metric sampling while the workers run, paced per the cadence.
        let wall_every = match cfg.sample_cadence {
            SampleCadence::WallClockMillis(ms) => Some(Duration::from_millis(ms)),
            SampleCadence::Activations(_) => None,
        };
        let mut last_sample = Instant::now();
        while handles.iter().any(|h| !h.is_finished()) {
            std::thread::sleep(Duration::from_millis(2));
            let Some(sample_every) = wall_every else {
                drain_snaps(
                    &mut evaluator,
                    ctl,
                    &mut cadence_last_acts,
                    &mut cadence_last_wall,
                );
                continue;
            };
            if last_sample.elapsed() < sample_every {
                continue;
            }
            last_sample = Instant::now();
            for (i, snap) in eta_snaps.iter().enumerate() {
                etas[i * n..(i + 1) * n].copy_from_slice(&snap.lock().unwrap());
            }
            let (dual, consensus, spread) = evaluator.evaluate(&etas, &measures);
            let acts = progress.load(Ordering::Relaxed);
            // clamp to the horizon: `sweeps` rounds `duration/interval`,
            // so the raw product can overshoot and un-sort the series
            let t_equiv =
                (acts as f64 / m as f64 * cfg.activation_interval).min(cfg.duration);
            ctl.sample(
                t_equiv,
                wall_t0.elapsed().as_secs_f64(),
                dual,
                consensus,
                spread,
                acts,
                rounds_of(acts),
            );
        }

        for h in handles {
            // worker panics are caught inside worker_loop (after the
            // barrier ledger is settled) and surface as Err here
            let joined =
                h.join().map_err(|_| "threaded worker died unrecoverably".to_string())?;
            let (mine, msgs, sweeps_done) = joined?;
            messages += msgs;
            sweeps_done_min = sweeps_done_min.min(sweeps_done);
            for (i, node) in mine {
                nodes_back[i] = Some(node);
            }
        }
        Ok(())
    })?;
    // The run window closes when the last worker finishes — recorded
    // before the final metric evaluation below so `dual_wall` (and the
    // speedup ratios derived from its last timestamp) measure the
    // algorithms' execution, not the evaluator.
    let run_window = wall_t0.elapsed().as_secs_f64();

    // Snapshots queued after the monitor's last pass (all of them, when
    // workers outpace the 2 ms drain tick) land before the horizon point.
    drain_snaps(&mut evaluator, ctl, &mut cadence_last_acts, &mut cadence_last_wall);
    let dropped = snap_dropped.load(Ordering::Relaxed);
    if dropped > 0 {
        eprintln!(
            "warn: activation-paced sampling shed {dropped} snapshots \
             (queue cap {} for this m·n); increase \
             SampleCadence::Activations(k) for this budget",
            shared.snap_cap
        );
    }

    // Final snapshot at a common θ index, mirroring the simulator's
    // horizon sample. Under cancellation the θ index and timestamp
    // reflect the work actually completed (the minimum sweep any worker
    // reached keeps the index common across nodes).
    let cancelled = cancel_token.is_cancelled();
    let acts_done = progress.load(Ordering::Relaxed);
    let k_final = if sync {
        sweeps_done_min
    } else {
        k_counter.load(Ordering::Relaxed).min(acts_done as usize)
    };
    let t_end = if cancelled {
        (acts_done as f64 / m as f64 * cfg.activation_interval).min(cfg.duration)
    } else {
        cfg.duration
    };
    let mut theta_final = ThetaSeq::new(m_theta);
    for (i, slot) in nodes_back.iter().enumerate() {
        let node = slot.as_ref().expect("worker returned every node");
        node.eta(&mut theta_final, k_final.max(1), &mut point);
        etas[i * n..(i + 1) * n].copy_from_slice(&point);
    }
    let (dual, consensus, spread) = evaluator.evaluate(&etas, &measures);
    let rounds_done = if sync { sweeps_done_min as u64 } else { 0 };
    ctl.sample(t_end, run_window, dual, consensus, spread, acts_done, rounds_done);

    ctl.emit(RunEvent::Finished(RunTotals {
        tag: cfg.tag(),
        algorithm: cfg.algorithm,
        activations: acts_done,
        rounds: rounds_done,
        messages,
        wire_messages: 0,
        events: acts_done,
        lambda_max,
        barycenter: evaluator.barycenter(),
        cancelled,
    }));
    debug_assert!(cancelled || acts_done == budget as u64);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_pacer_drain_settles_the_protocol_for_a_failed_worker() {
        // One worker does a single round of real work then "fails";
        // its drain must keep serving barrier phases so the healthy
        // worker (which owes 4 waits) is never stranded. A regression
        // here deadlocks the test rather than passing silently.
        let barrier = Barrier::new(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                let p = SyncPacer::new(&barrier, 4);
                p.wait();
                p.drain();
                assert_eq!(p.waited.get(), 4);
            });
            s.spawn(|| {
                let p = SyncPacer::new(&barrier, 4);
                for _ in 0..4 {
                    p.wait();
                }
                p.drain(); // completed worker: drain is a no-op
                assert_eq!(p.waited.get(), 4);
            });
        });
    }
}
