//! `a2dwb` — leader binary: run decentralized Wasserstein-barycenter
//! experiments from the command line.
//!
//! ```text
//! a2dwb gaussian --algorithm a2dwb --topology cycle --nodes 50 --duration 30
//! a2dwb gaussian --executor threads --workers 4 --algorithm a2dwb
//! a2dwb mnist    --digit 3 --topology er:0.1 --nodes 50
//! a2dwb sweep    --nodes 30 --duration 20          # all algos × topologies
//! a2dwb speedup  --workers 4 --nodes 16            # async vs sync wall-clock
//! a2dwb oracle   --backend pjrt --m 32 --n 100     # oracle micro-check
//! a2dwb inspect  --topology star --nodes 100       # graph spectral info
//! ```

use a2dwb::algo::wbp::DiagCoef;
use a2dwb::cli::Args;
use a2dwb::coordinator::{run_experiment, ExperimentConfig};
use a2dwb::exec::ExecutorSpec;
use a2dwb::graph::{Graph, TopologySpec};
use a2dwb::measures::MeasureSpec;
use a2dwb::metrics::{ascii_summary, write_csv};
use a2dwb::ot::OracleBackendSpec;
use a2dwb::prelude::AlgorithmKind;

const SUBCOMMANDS: &[&str] =
    &["gaussian", "mnist", "sweep", "speedup", "oracle", "inspect"];

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("gaussian") => cmd_experiment(&args, false),
        Some("mnist") => cmd_experiment(&args, true),
        Some("sweep") => cmd_sweep(&args),
        Some("speedup") => cmd_speedup(&args),
        Some("oracle") => cmd_oracle(&args),
        Some("inspect") => cmd_inspect(&args),
        _ => {
            eprintln!("usage: a2dwb <{}> [--opt value ...]", SUBCOMMANDS.join("|"));
            eprintln!("common options:");
            eprintln!("  --nodes N --topology T --algorithm A --duration S --seed K");
            eprintln!("  --beta B --gamma-scale G --samples M --backend native|pjrt");
            eprintln!("  --executor sim|threads --workers W  (execution backend)");
            eprintln!("  --out results/run.csv  (CSV of the metric series)");
            2
        }
    };
    std::process::exit(code);
}

/// Build an ExperimentConfig from shared CLI options.
fn config_from_args(args: &Args, mnist: bool) -> Result<ExperimentConfig, String> {
    let mut cfg = if mnist {
        ExperimentConfig::mnist_default(args.get::<u8>("digit", 2)?)
    } else {
        ExperimentConfig::gaussian_default()
    };
    cfg.nodes = args.get("nodes", cfg.nodes)?;
    cfg.seed = args.get("seed", cfg.seed)?;
    cfg.topology = TopologySpec::parse(&args.get_str("topology", "complete"), cfg.seed)?;
    cfg.algorithm = AlgorithmKind::parse(&args.get_str("algorithm", "a2dwb"))?;
    cfg.beta = args.get("beta", cfg.beta)?;
    cfg.gamma_scale = args.get("gamma-scale", cfg.gamma_scale)?;
    cfg.samples_per_activation = args.get("samples", cfg.samples_per_activation)?;
    cfg.eval_samples = args.get("eval-samples", cfg.eval_samples)?;
    cfg.duration = args.get("duration", cfg.duration)?;
    cfg.activation_interval = args.get("activation-interval", cfg.activation_interval)?;
    cfg.metric_interval = args.get("metric-interval", cfg.metric_interval)?;
    cfg.compute_time = args.get("compute-time", cfg.compute_time)?;
    if mnist {
        let side = args.get("side", 28usize)?;
        cfg.measure = MeasureSpec::Digits {
            digit: args.get::<u8>("digit", 2)?,
            side,
            idx_path: args.get_opt("idx-path").map(str::to_string),
        };
    } else {
        cfg.measure = MeasureSpec::Gaussian { n: args.get("support", 100usize)? };
    }
    cfg.backend = match args.get_str("backend", "native").as_str() {
        "native" => OracleBackendSpec::Native,
        "pjrt" => OracleBackendSpec::Pjrt {
            artifacts_dir: args.get_str("artifacts", "artifacts"),
        },
        other => return Err(format!("unknown backend '{other}'")),
    };
    let workers = args.get("workers", 0usize)?;
    cfg.executor = ExecutorSpec::parse(&args.get_str("executor", "sim"), workers)?;
    if args.has_flag("paper-literal-diag") {
        cfg.diag = DiagCoef::PaperLiteral;
    }
    Ok(cfg)
}

/// Wall-clock speedup of A²DWB over DCWB on the threaded executor at an
/// equal iteration budget — the paper's waiting-overhead claim on real
/// threads. The simulator's virtual-time verdict is printed alongside.
fn cmd_speedup(args: &Args) -> i32 {
    let mut cfg = match config_from_args(args, false) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    // CI-friendly scale unless overridden; a small per-activation
    // compute cost makes the barrier's waiting overhead visible.
    let scale = || -> Result<(usize, f64, usize), String> {
        Ok((
            args.get("nodes", 16usize)?,
            args.get("duration", 4.0)?,
            args.get("workers", 4usize)?,
        ))
    };
    let (nodes, duration, workers_arg) = match scale() {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    cfg.nodes = nodes;
    cfg.duration = duration;
    if args.get_opt("compute-time").is_none() {
        cfg.compute_time = 0.0005;
    }
    let workers = match cfg.executor {
        ExecutorSpec::Threads { workers } => workers,
        ExecutorSpec::Sim => workers_arg.max(1),
    };

    println!(
        "== wall-clock speedup: a2dwb vs dcwb, {} nodes, {} workers, equal budget ==",
        cfg.nodes, workers
    );
    let (a, s) = match a2dwb::exec::run_speedup_pair(&cfg, workers) {
        Ok(pair) => pair,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!("{}", a.summary());
    println!("{}", s.summary());
    println!(
        "SPEEDUP threads workers={workers} a2dwb={:.3}s dcwb={:.3}s -> {:.2}x \
         (dual: a2dwb {:.6} vs dcwb {:.6})",
        a.wall_seconds,
        s.wall_seconds,
        s.wall_seconds / a.wall_seconds.max(1e-12),
        a.final_dual_objective(),
        s.final_dual_objective(),
    );
    // simulator reference on the same configuration (virtual time)
    cfg.executor = ExecutorSpec::Sim;
    cfg.compute_time = 0.0;
    for alg in [AlgorithmKind::A2dwb, AlgorithmKind::Dcwb] {
        cfg.algorithm = alg;
        match run_experiment(&cfg) {
            Ok(r) => println!("sim reference: {}", r.summary()),
            Err(e) => {
                eprintln!("error [sim {}]: {e}", alg.name());
                return 1;
            }
        }
    }
    0
}

fn cmd_experiment(args: &Args, mnist: bool) -> i32 {
    let cfg = match config_from_args(args, mnist) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    println!(
        "running {} on {} ({} nodes, {:.0}s virtual, backend {:?})",
        cfg.algorithm.name(),
        cfg.topology.name(),
        cfg.nodes,
        cfg.duration,
        cfg.backend
    );
    match run_experiment(&cfg) {
        Ok(report) => {
            println!("{}", report.summary());
            println!(
                "{}",
                ascii_summary(
                    &[
                        &report.dual_objective,
                        &report.consensus,
                        &report.primal_spread,
                        &report.dual_wall,
                    ],
                    48
                )
            );
            if let Some(out) = args.get_opt("out") {
                if let Err(e) = write_csv(
                    out,
                    &[&report.dual_objective, &report.consensus, &report.primal_spread],
                ) {
                    eprintln!("error writing {out}: {e}");
                    return 1;
                }
                println!("wrote {out}");
                // the wall-clock axis lives in its own file: its time
                // column is seconds of real time, not virtual time
                let wall_out = format!("{out}.wall.csv");
                if let Err(e) = write_csv(&wall_out, &[&report.dual_wall]) {
                    eprintln!("error writing {wall_out}: {e}");
                    return 1;
                }
                println!("wrote {wall_out}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    let topologies = ["complete", "er:0.1", "cycle", "star"];
    for topo in topologies {
        for alg in AlgorithmKind::all() {
            let mut cfg = match config_from_args(args, false) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            cfg.topology = TopologySpec::parse(topo, cfg.seed).unwrap();
            cfg.algorithm = alg;
            match run_experiment(&cfg) {
                Ok(r) => println!("{}", r.summary()),
                Err(e) => {
                    eprintln!("error [{topo}/{}]: {e}", alg.name());
                    return 1;
                }
            }
        }
    }
    0
}

fn cmd_oracle(args: &Args) -> i32 {
    use a2dwb::measures::CostRows;
    use a2dwb::ot::DualOracle;
    let m: usize = args.get("m", 32usize).unwrap_or(32);
    let n: usize = args.get("n", 100usize).unwrap_or(100);
    let beta: f64 = args.get("beta", 0.02).unwrap_or(0.02);
    let mut rng = a2dwb::rng::Rng64::new(args.get("seed", 1u64).unwrap_or(1));
    let eta: Vec<f64> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let mut cost = CostRows::new(m, n);
    for v in cost.data.iter_mut() {
        *v = rng.uniform();
    }
    let mut grad_native = vec![0.0; n];
    let mut native = a2dwb::ot::NativeOracle::default();
    let val_native = native.eval(&eta, &cost, beta, &mut grad_native);
    println!("native : val={val_native:.6}");
    if args.get_str("backend", "native") == "pjrt" {
        let dir = args.get_str("artifacts", "artifacts");
        match a2dwb::runtime::PjrtOracle::load(&dir, m, n) {
            Ok(mut pjrt) => {
                let mut grad_pjrt = vec![0.0; n];
                let val_pjrt = pjrt.eval(&eta, &cost, beta, &mut grad_pjrt);
                let max_diff = grad_native
                    .iter()
                    .zip(&grad_pjrt)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                println!("pjrt   : val={val_pjrt:.6} max|Δgrad|={max_diff:.3e}");
                if max_diff > 1e-4 || (val_native - val_pjrt).abs() > 1e-4 {
                    eprintln!("BACKEND MISMATCH");
                    return 1;
                }
                println!("backends agree");
            }
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_inspect(args: &Args) -> i32 {
    let seed = args.get("seed", 42u64).unwrap_or(42);
    let nodes = args.get("nodes", 50usize).unwrap_or(50);
    let topo = match TopologySpec::parse(&args.get_str("topology", "complete"), seed) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let g = Graph::build(nodes, topo);
    println!("topology   : {}", topo.name());
    println!("nodes      : {}", g.num_nodes());
    println!("edges      : {}", g.num_edges());
    println!("max degree : {}", g.max_degree());
    println!("connected  : {}", g.is_connected());
    println!("λ_max(W̄)  : {:.4}", g.lambda_max());
    if nodes <= 200 {
        println!("λ₂(W̄)     : {:.6} (algebraic connectivity)", g.algebraic_connectivity());
    }
    0
}
