//! Metric evaluation with common random numbers.
//!
//! The paper plots (a) the dual objective value and (b) the consensus
//! distance over time (§4). Both are functions of the current dual
//! iterates η̄_i. To make curves comparable *between algorithms* we
//! evaluate every snapshot on the same fixed per-node sample batch
//! (drawn once from the master seed), so the metric is a deterministic
//! function of the state — exactly the common-random-numbers practice
//! the shared-seed activation scheme of §3.3 enables.

use crate::graph::Graph;
use crate::kernel::{self, KernelImpl};
use crate::linalg::CsrMatrix;
use crate::measures::{NodeMeasure, Samples};
use crate::obs::{Counter, HistKind, Telemetry};
use crate::ot::OracleScratch;
use crate::rng::Rng64;

pub struct MetricsEvaluator {
    n: usize,
    beta: f64,
    /// Per-node frozen evaluation samples; each snapshot rebinds them
    /// zero-copy through [`NodeMeasure::cost_rows`] — no cost rows are
    /// materialized on the metric path either.
    samples: Vec<Samples>,
    laplacian: CsrMatrix,
    // scratch
    scratch: OracleScratch,
    /// Stacked primal blocks (m·n) of the last evaluated snapshot.
    primal: Vec<f64>,
    // Batched-evaluation staging (see [`Self::evaluate_many`]): B η̄/∇
    // blocks of n, B values, and B stacked primals — all reused.
    batch_etas: Vec<f64>,
    batch_grads: Vec<f64>,
    batch_vals: Vec<f64>,
    batch_primal: Vec<f64>,
    /// Batch-dispatch telemetry sink ([`Self::attach_obs`]). Kept off
    /// the scratch on purpose: the metric path's `OraclePasses` tally
    /// is pinned by goldens, and this registry only ever receives the
    /// dispatch-shape counters (`BatchDispatches`, `BatchOccupancy`).
    obs: Option<std::sync::Arc<Telemetry>>,
}

impl MetricsEvaluator {
    pub fn new(
        graph: &Graph,
        measures: &[Box<dyn NodeMeasure>],
        beta: f64,
        eval_samples: usize,
        seed: u64,
    ) -> Self {
        let m = graph.num_nodes();
        assert_eq!(measures.len(), m);
        let n = measures[0].support_size();
        let mut rng = Rng64::new(seed ^ 0x4556_414C);
        let samples: Vec<Samples> = measures
            .iter()
            .map(|msr| msr.draw_samples(&mut rng, eval_samples))
            .collect();
        Self {
            n,
            beta,
            samples,
            laplacian: graph.laplacian_csr(),
            scratch: OracleScratch::default(),
            primal: vec![0.0; m * n],
            batch_etas: Vec::new(),
            batch_grads: Vec::new(),
            batch_vals: Vec::new(),
            batch_primal: Vec::new(),
            obs: None,
        }
    }

    /// Lane width for every metric oracle pass (default
    /// [`KernelImpl::Scalar`] — the golden-stable metric path).
    pub fn set_kernel(&mut self, kernel: KernelImpl) {
        self.scratch.set_kernel(kernel);
    }

    /// Record batch-dispatch shape (one [`Counter::BatchDispatches`]
    /// per per-node batched pass, the snapshot count as
    /// [`HistKind::BatchOccupancy`]) into `obs`. Relaxed counters only
    /// — results are bit-identical with or without.
    pub fn attach_obs(&mut self, obs: std::sync::Arc<Telemetry>) {
        self.obs = Some(obs);
    }

    /// Entry-wise mean of the m primal blocks — the one definition of
    /// the network mean shared by [`Self::evaluate`] (primal spread)
    /// and [`Self::barycenter`].
    fn network_mean(&self) -> Vec<f64> {
        let m = self.primal.len() / self.n;
        let mut mean = vec![0.0; self.n];
        for i in 0..m {
            for l in 0..self.n {
                mean[l] += self.primal[i * self.n + l];
            }
        }
        for v in &mut mean {
            *v /= m as f64;
        }
        mean
    }

    /// Evaluate (dual objective, consensus distance, primal spread) at
    /// the stacked dual snapshot `etas` (m rows of n, row-major).
    ///
    /// * dual objective = Σ_i Ŵ*_{β,μ_i}(η̄_i) on the frozen batches;
    /// * consensus = xᵀ(W̄⊗I)x with x_i = primal softmax block;
    /// * spread = mean_i ‖x_i − x̄‖₁ (interpretable companion).
    pub fn evaluate(
        &mut self,
        etas: &[f64],
        measures: &[Box<dyn NodeMeasure>],
    ) -> (f64, f64, f64) {
        self.evaluate_many(&[etas], measures)[0]
    }

    /// Evaluate B stacked dual snapshots in one batched oracle sweep:
    /// each node's cost rows are bound **once** and applied to all B
    /// snapshots' η̄_i blocks via [`kernel::dual_oracle_batch`] — the
    /// digits table streams through cache once per node instead of once
    /// per (node, snapshot).
    ///
    /// Per snapshot, the returned `(dual, consensus, spread)` triple is
    /// bitwise-identical to a sequential [`Self::evaluate`] loop under
    /// the scalar kernel (the batch oracle's parity contract); the last
    /// snapshot's primal blocks are left in place, so
    /// [`Self::barycenter`] refers to it exactly as after a sequential
    /// loop. Returns one triple per snapshot; empty input is fine.
    pub fn evaluate_many(
        &mut self,
        snaps: &[&[f64]],
        measures: &[Box<dyn NodeMeasure>],
    ) -> Vec<(f64, f64, f64)> {
        let b = snaps.len();
        if b == 0 {
            return Vec::new();
        }
        let m = measures.len();
        let n = self.n;
        for snap in snaps {
            assert_eq!(snap.len(), m * n);
        }
        self.batch_etas.resize(b * n, 0.0);
        self.batch_grads.resize(b * n, 0.0);
        self.batch_vals.resize(b, 0.0);
        self.batch_primal.resize(b * m * n, 0.0);
        if let Some(obs) = &self.obs {
            obs.add(Counter::BatchDispatches, m as u64);
            obs.record(HistKind::BatchOccupancy, b as u64);
        }
        let mut duals = vec![0.0; b];
        for i in 0..m {
            for (bi, snap) in snaps.iter().enumerate() {
                self.batch_etas[bi * n..(bi + 1) * n]
                    .copy_from_slice(&snap[i * n..(i + 1) * n]);
            }
            let rows = measures[i].cost_rows(&self.samples[i]);
            kernel::dual_oracle_batch(
                &self.batch_etas,
                &rows,
                self.beta,
                &mut self.batch_grads,
                &mut self.batch_vals,
                &mut self.scratch,
            );
            for bi in 0..b {
                duals[bi] += self.batch_vals[bi];
                self.batch_primal[(bi * m + i) * n..(bi * m + i + 1) * n]
                    .copy_from_slice(&self.batch_grads[bi * n..(bi + 1) * n]);
            }
        }
        let mut out = Vec::with_capacity(b);
        for bi in 0..b {
            self.primal
                .copy_from_slice(&self.batch_primal[bi * m * n..(bi + 1) * m * n]);
            let consensus = self.laplacian.block_quad_form(&self.primal, n);
            // primal spread: mean L1 distance to the network mean
            let mean = self.network_mean();
            let mut spread = 0.0;
            for i in 0..m {
                for l in 0..n {
                    spread += (self.primal[i * n + l] - mean[l]).abs();
                }
            }
            spread /= m as f64;
            out.push((duals[bi], consensus.max(0.0), spread));
        }
        out
    }

    /// The network-mean primal block from the last `evaluate` call —
    /// the barycenter estimate ν̂ the system outputs.
    pub fn barycenter(&self) -> Vec<f64> {
        self.network_mean()
    }

    pub fn support_size(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TopologySpec;
    use crate::measures::MeasureSpec;

    fn setup() -> (Graph, Vec<Box<dyn NodeMeasure>>, MetricsEvaluator) {
        let g = Graph::build(5, TopologySpec::Cycle);
        let ms = MeasureSpec::Gaussian { n: 12 }.build_network(5, 3);
        let ev = MetricsEvaluator::new(&g, &ms, 0.1, 16, 9);
        (g, ms, ev)
    }

    #[test]
    fn consensus_zero_at_equal_potentials() {
        let (_, ms, mut ev) = setup();
        // identical η̄ across nodes does NOT give zero consensus (the
        // measures differ), but identical *primal* blocks would. Check
        // instead: evaluation is deterministic and non-negative.
        let etas = vec![0.0; 5 * 12];
        let (d1, c1, s1) = ev.evaluate(&etas, &ms);
        let (d2, c2, s2) = ev.evaluate(&etas, &ms);
        assert_eq!((d1, c1, s1), (d2, c2, s2));
        assert!(c1 >= 0.0 && s1 >= 0.0);
    }

    #[test]
    fn identical_measures_consensus_vanishes() {
        // degenerate measures (all mass on one pixel) make every node's
        // eval samples identical, so equal η̄ ⇒ equal primal blocks ⇒
        // the consensus distance is exactly 0.
        use crate::measures::digits::{DigitMeasure, GridGeometry};
        let g = Graph::build(4, TopologySpec::Complete);
        let geom = std::sync::Arc::new(GridGeometry::new(3));
        let mut img = vec![0.0; 9];
        img[4] = 1.0;
        let ms: Vec<Box<dyn NodeMeasure>> = (0..4)
            .map(|_| {
                Box::new(DigitMeasure::new(img.clone(), geom.clone()))
                    as Box<dyn NodeMeasure>
            })
            .collect();
        let mut ev = MetricsEvaluator::new(&g, &ms, 0.1, 8, 11);
        let etas = vec![0.25; 4 * 9];
        let (_, consensus, spread) = ev.evaluate(&etas, &ms);
        assert!(consensus < 1e-12, "consensus {consensus}");
        assert!(spread < 1e-12);
    }

    #[test]
    fn evaluate_many_matches_sequential_evaluates_bitwise() {
        let (_, ms, mut ev) = setup();
        let mut rng = crate::rng::Rng64::new(99);
        let snaps: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..5 * 12).map(|_| 0.2 * rng.normal()).collect())
            .collect();
        let seq: Vec<(f64, f64, f64)> =
            snaps.iter().map(|s| ev.evaluate(s, &ms)).collect();
        let bary_seq = ev.barycenter();
        let views: Vec<&[f64]> = snaps.iter().map(|s| s.as_slice()).collect();
        let many = ev.evaluate_many(&views, &ms);
        for (k, ((d1, c1, s1), (d2, c2, s2))) in seq.iter().zip(&many).enumerate()
        {
            assert_eq!(d1.to_bits(), d2.to_bits(), "dual, snapshot {k}");
            assert_eq!(c1.to_bits(), c2.to_bits(), "consensus, snapshot {k}");
            assert_eq!(s1.to_bits(), s2.to_bits(), "spread, snapshot {k}");
        }
        // the batch leaves the last snapshot's primal in place
        assert_eq!(ev.barycenter(), bary_seq);
        assert!(ev.evaluate_many(&[], &ms).is_empty());
    }

    #[test]
    fn barycenter_is_distribution() {
        let (_, ms, mut ev) = setup();
        let etas = vec![0.1; 5 * 12];
        ev.evaluate(&etas, &ms);
        let b = ev.barycenter();
        assert_eq!(b.len(), 12);
        assert!((b.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(b.iter().all(|&x| x >= 0.0));
    }
}
