//! Sharded execution: the mailbox grid split across processes.
//!
//! One **shard** = one process (or, in the in-process harness
//! [`run_mesh_threads`], one thread with its own TCP sockets) owning a
//! contiguous block of network nodes. The shard runs its local nodes
//! on the shared scheduling core
//! ([`NodeScheduler`](crate::exec::sched::NodeScheduler) over
//! `plan.local()`, with a `workers`-wide in-shard pool — `--processes
//! P --workers W` scales P×W); the node body is the same
//! [`activate_node`](crate::exec::activate_node) as every other
//! backend, and only the transport and the round gate differ:
//!
//! * **intra-shard** edges use the lock-based freshest-wins slots of a
//!   local [`MailboxGrid`] replica, exactly like the threaded executor;
//! * **cross-shard** edges serialize the gradient once per *peer
//!   shard* (not per edge — the receiving shard's grid replica fans it
//!   out to every local neighbor of the source) and ship it over TCP
//!   through a writer thread per peer; a reader thread per peer feeds
//!   incoming gradients straight into the local grid.
//!
//! The shard reports no metrics of its own — network-global metrics
//! (dual objective, consensus) need every node's iterate, so shards
//! ship their final (and, under lockstep recording, per-sweep) dual
//! iterates to the aggregator, which stitches them and evaluates the
//! usual [`MetricsEvaluator`] series. Frame sizes are bounded by
//! [`MAX_FRAME_BYTES`](super::MAX_FRAME_BYTES); per-sweep recording is
//! a validation feature for CI-scale instances, not a paper-scale
//! telemetry path.

use std::collections::BTreeMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::codec::{
    self, FrameReader, HelloFrame, MarkerPhase, ReadEvent, ShardReport, WireMsg,
};
use super::{Pacing, ShardPlan};
use crate::algo::wbp::WbpNode;
use crate::algo::{AlgorithmKind, ThetaSeq};
use crate::coordinator::{
    CancelToken, ExperimentConfig, ExperimentReport, MetricsEvaluator, RunEvent,
    RunObserver,
};
use crate::exec::sched::{
    ClaimOrder, FailPoint, FreeGate, LocalGate, NodeScheduler, PhaseBarrier, RoundGate,
    SchedTransport, SchedulerSpec, SweepHooks,
};
use crate::exec::transport::MailboxGrid;
use crate::exec::Transport;
use crate::graph::Graph;
use crate::measures::{MeasureSpec, NodeMeasure, Samples};
use crate::metrics::Series;
use crate::obs::{Counter, Telemetry, TelemetrySnapshot};
use crate::ot::OracleBackendSpec;
use crate::rng::Rng64;

/// How long socket reads block before the reader re-checks its
/// shutdown flag (the [`FrameReader`] preserves stream position across
/// these timeouts).
const READ_POLL: Duration = Duration::from_millis(200);
/// How long a finished shard tolerates **continuous silence** (no
/// frame at all, measured from the last one received) from a peer that
/// has not said `Bye` before declaring it crashed. Any frame re-arms
/// the window, so a slow but active peer is drained indefinitely.
const DRAIN_GRACE: Duration = Duration::from_secs(30);
/// How many sweeps ahead of the slowest shard the snapshot collector
/// keeps reading a fast shard's trajectory stream before throttling it
/// (TCP backpressure then paces the shard). Bounds
/// [`StreamAggregator`]'s pending memory to `MAX_SNAPSHOT_LEAD ×
/// shards × block` under free-pacing skew instead of the full
/// trajectory.
const MAX_SNAPSHOT_LEAD: u64 = 64;

fn algo_code(a: AlgorithmKind) -> u8 {
    match a {
        AlgorithmKind::A2dwb => 0,
        AlgorithmKind::A2dwbn => 1,
        AlgorithmKind::Dcwb => 2,
    }
}

/// Filename tag of an aggregated mesh run: same shape as
/// [`ExperimentConfig::tag`] but with the executor token replaced by
/// `netP` — the run executed on P shard processes, not on the
/// in-process backend `cfg.executor` names.
fn mesh_tag(cfg: &ExperimentConfig, shards: usize) -> String {
    format!(
        "{}_{}_{}_m{}_net{}_s{}",
        cfg.algorithm.name(),
        cfg.topology.name(),
        cfg.measure.name(),
        cfg.nodes,
        shards,
        cfg.seed
    )
}

/// FNV-1a digest of every experiment knob that shapes the dynamics but
/// has no explicit [`HelloFrame`] field: β, γ-scale, batch sizes,
/// topology (with the ER edge probability), measure family (n / digit
/// / side / idx path), fault model, intervals, compute time, and the
/// diag variant. Two shards whose digests differ refuse the handshake
/// — β or topology disagreements must fail as loudly as a seed
/// disagreement, never silently mix gradients. Floats are hashed by
/// `to_bits` (fault-model and topology floats via their
/// shortest-roundtrip `Debug`), so the digest is exactly as strict as
/// the bit-level parity contract.
pub fn config_digest(cfg: &ExperimentConfig) -> u64 {
    let desc = format!(
        "{:?}|{:?}|{:x}|{:x}|{}|{}|{:x}|{:x}|{:x}|{:?}|{:?}|{:?}",
        cfg.measure,
        cfg.topology,
        cfg.beta.to_bits(),
        cfg.gamma_scale.to_bits(),
        cfg.samples_per_activation,
        cfg.eval_samples,
        cfg.duration.to_bits(),
        cfg.activation_interval.to_bits(),
        cfg.compute_time.to_bits(),
        cfg.faults,
        cfg.diag,
        cfg.kernel,
    );
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in desc.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ------------------------------------------------------------ grid

/// The full-network routing table with shard-local storage: publishing
/// is identical to the single-process [`MailboxGrid`] (every directed
/// edge has a slot), but only slots whose *destination* is local carry
/// an n-vector — remote-destination slots are routing stubs that cost
/// an `Arc` pointer swap and nothing else
/// ([`MailboxGrid::new_for`]).
pub struct ShardedMailboxGrid {
    plan: ShardPlan,
    grid: MailboxGrid,
    /// Per local node (index − `plan.local().start`): the peer shards
    /// owning at least one neighbor, sorted and deduped — the wire
    /// fan-out of one broadcast.
    remote_fanout: Vec<Vec<usize>>,
}

impl ShardedMailboxGrid {
    pub fn new(graph: &Graph, n: usize, plan: ShardPlan) -> Self {
        let local = plan.local();
        let grid = MailboxGrid::new_for(graph, n, |j| local.contains(&j));
        let remote_fanout = local
            .clone()
            .map(|i| {
                let mut peers: Vec<usize> = graph
                    .neighbors(i)
                    .iter()
                    .map(|&j| plan.owner(j))
                    .filter(|&p| p != plan.shard)
                    .collect();
                peers.sort_unstable();
                peers.dedup();
                peers
            })
            .collect();
        Self { plan, grid, remote_fanout }
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Route the local grid replica's mailbox telemetry (publishes,
    /// freshest-wins overwrites, stale drops, stamp-lag reads) into
    /// `obs`. Call before the grid is shared.
    pub fn attach_obs(&mut self, obs: Arc<Telemetry>) {
        self.grid.attach_obs(obs);
    }

    /// The local grid replica (reader threads publish remote gradients
    /// here; workers collect from it).
    pub fn grid(&self) -> &MailboxGrid {
        &self.grid
    }

    /// Peer shards that must receive node `src`'s broadcasts.
    pub fn fanout(&self, src: usize) -> &[usize] {
        &self.remote_fanout[src - self.plan.local().start]
    }
}

/// [`Transport`] over a [`ShardedMailboxGrid`] plus per-peer writer
/// channels. `messages` counts directed-edge deliveries (the same
/// granularity every other backend reports); `wire_messages` counts
/// TCP frames — the dedup between the two is what sharding buys.
pub struct ShardedTransport<'a> {
    sgrid: &'a ShardedMailboxGrid,
    senders: &'a [Option<mpsc::Sender<Arc<Vec<u8>>>>],
    pub messages: u64,
    pub wire_messages: u64,
}

impl<'a> ShardedTransport<'a> {
    pub fn new(
        sgrid: &'a ShardedMailboxGrid,
        senders: &'a [Option<mpsc::Sender<Arc<Vec<u8>>>>],
    ) -> Self {
        Self { sgrid, senders, messages: 0, wire_messages: 0 }
    }
}

impl Transport for ShardedTransport<'_> {
    fn broadcast(&mut self, src: usize, stamp: u64, grad: Arc<Vec<f64>>) {
        self.messages += self.sgrid.grid.publish(src, stamp, &grad);
        let peers = self.sgrid.fanout(src);
        if peers.is_empty() {
            return;
        }
        let frame = Arc::new(codec::encode_grad(src as u32, stamp, &grad));
        for &p in peers {
            if let Some(tx) = &self.senders[p] {
                // a send error means the writer already recorded a
                // mesh failure; the run loop will surface it
                if tx.send(frame.clone()).is_ok() {
                    self.wire_messages += 1;
                }
            }
        }
    }

    fn collect(&mut self, dst: usize, node: &mut WbpNode, reader_stamp: u64) {
        self.sgrid.grid.collect(dst, node, reader_stamp);
    }
}

impl SchedTransport for ShardedTransport<'_> {
    fn counters(&self) -> (u64, u64) {
        (self.messages, self.wire_messages)
    }
}

// ------------------------------------------------------------ marker board

/// Cross-shard progress markers, updated by reader threads and waited
/// on by the run loop. All waits are condvar-based with a hard
/// timeout, and any mesh error wakes every waiter immediately.
struct Board {
    state: Mutex<BoardState>,
    cv: Condvar,
}

struct BoardState {
    init: Vec<bool>,
    /// Completed sweeps per shard (lockstep): `r + 1` after `Done(SweepDone, r)`.
    sweeps: Vec<u64>,
    /// Completed publish phases per shard (DCWB).
    published: Vec<u64>,
    /// Completed collect phases per shard (DCWB).
    collected: Vec<u64>,
    error: Option<String>,
}

impl Board {
    fn new(shards: usize) -> Self {
        Self {
            state: Mutex::new(BoardState {
                init: vec![false; shards],
                sweeps: vec![0; shards],
                published: vec![0; shards],
                collected: vec![0; shards],
                error: None,
            }),
            cv: Condvar::new(),
        }
    }

    fn mark(&self, shard: usize, phase: MarkerPhase, value: u64) {
        let mut s = self.state.lock().unwrap();
        if shard < s.init.len() {
            match phase {
                MarkerPhase::Init => s.init[shard] = true,
                MarkerPhase::SweepDone => s.sweeps[shard] = s.sweeps[shard].max(value + 1),
                MarkerPhase::RoundPublished => {
                    s.published[shard] = s.published[shard].max(value + 1)
                }
                MarkerPhase::RoundCollected => {
                    s.collected[shard] = s.collected[shard].max(value + 1)
                }
            }
        }
        drop(s);
        self.cv.notify_all();
    }

    fn fail(&self, err: String) {
        let mut s = self.state.lock().unwrap();
        if s.error.is_none() {
            s.error = Some(err);
        }
        drop(s);
        self.cv.notify_all();
    }

    fn error(&self) -> Option<String> {
        self.state.lock().unwrap().error.clone()
    }

    fn wait_until(
        &self,
        timeout: Duration,
        what: &str,
        pred: impl Fn(&BoardState) -> bool,
    ) -> Result<(), String> {
        let deadline = Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(e) = &s.error {
                return Err(format!("mesh failed while waiting for {what}: {e}"));
            }
            if pred(&s) {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(format!("timed out after {timeout:?} waiting for {what}"));
            }
            let (guard, _) = self.cv.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }
}

// ------------------------------------------------------------ mesh

/// The live connection fabric of one shard: per-peer writer channels,
/// reader threads feeding the grid, and the marker board.
struct Mesh {
    shard: usize,
    senders: Vec<Option<mpsc::Sender<Arc<Vec<u8>>>>>,
    board: Arc<Board>,
    stop: Arc<AtomicBool>,
    readers: Vec<std::thread::JoinHandle<()>>,
    writers: Vec<std::thread::JoinHandle<()>>,
}

fn dial_retry(addr: &str, deadline: Instant) -> Result<TcpStream, String> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(format!("connecting to peer {addr}: {e}"));
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// Read the peer's handshake (tolerating read-timeout polls).
fn handshake_read(
    fr: &mut FrameReader<TcpStream>,
    deadline: Instant,
    addr: &str,
) -> Result<HelloFrame, String> {
    loop {
        match fr.next_frame()? {
            ReadEvent::Msg(WireMsg::Hello(h)) => return Ok(h),
            ReadEvent::Msg(other) => {
                return Err(format!("peer {addr} sent {other:?} before Hello"))
            }
            ReadEvent::Eof => return Err(format!("peer {addr} closed during handshake")),
            ReadEvent::Timeout => {
                if Instant::now() >= deadline {
                    return Err(format!("handshake with {addr} timed out"));
                }
            }
        }
    }
}

fn prepare_stream(stream: &TcpStream) -> Result<(), String> {
    stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
    stream
        .set_read_timeout(Some(READ_POLL))
        .map_err(|e| format!("set_read_timeout: {e}"))?;
    Ok(())
}

impl Mesh {
    /// Connect the full peer mesh: this shard dials every higher-index
    /// peer and accepts one connection from every lower-index peer
    /// (one duplex TCP stream per unordered pair), exchanging and
    /// validating [`HelloFrame`]s on each.
    fn establish(
        plan: ShardPlan,
        listener: TcpListener,
        peer_addrs: &[String],
        hello: HelloFrame,
        sgrid: Arc<ShardedMailboxGrid>,
        n: usize,
        timeout: Duration,
        obs: Arc<Telemetry>,
    ) -> Result<Mesh, String> {
        let shards = plan.shards;
        if peer_addrs.len() != shards {
            return Err(format!(
                "--peers lists {} addresses for {} shards",
                peer_addrs.len(),
                shards
            ));
        }
        let deadline = Instant::now() + timeout;
        let board = Arc::new(Board::new(shards));
        let stop = Arc::new(AtomicBool::new(false));
        let mut conns: Vec<Option<(TcpStream, FrameReader<TcpStream>)>> =
            (0..shards).map(|_| None).collect();

        // Dial up: this shard initiates toward every higher index.
        for t in plan.shard + 1..shards {
            let addr = &peer_addrs[t];
            let stream = dial_retry(addr, deadline)?;
            prepare_stream(&stream)?;
            codec::write_frame(&mut (&stream), &codec::encode_hello(&hello), Some(&obs))?;
            let clone = stream.try_clone().map_err(|e| format!("try_clone: {e}"))?;
            let mut fr = FrameReader::new(clone);
            fr.attach_obs(obs.clone());
            let peer = handshake_read(&mut fr, deadline, addr)?;
            hello.check_compatible(&peer)?;
            if peer.shard as usize != t {
                return Err(format!("{addr} answered as shard {}, expected {t}", peer.shard));
            }
            conns[t] = Some((stream, fr));
        }

        // Accept down: every lower index dials us.
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("listener nonblocking: {e}"))?;
        let mut accepted = 0usize;
        while accepted < plan.shard {
            match listener.accept() {
                Ok((stream, from)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| format!("stream blocking: {e}"))?;
                    prepare_stream(&stream)?;
                    let clone =
                        stream.try_clone().map_err(|e| format!("try_clone: {e}"))?;
                    let mut fr = FrameReader::new(clone);
                    fr.attach_obs(obs.clone());
                    let peer = handshake_read(&mut fr, deadline, &from.to_string())?;
                    hello.check_compatible(&peer)?;
                    let t = peer.shard as usize;
                    if t >= plan.shard {
                        return Err(format!(
                            "shard {t} dialed shard {} (higher shards must be dialed, not dial)",
                            plan.shard
                        ));
                    }
                    if conns[t].is_some() {
                        return Err(format!("duplicate connection from shard {t}"));
                    }
                    codec::write_frame(
                        &mut (&stream),
                        &codec::encode_hello(&hello),
                        Some(&obs),
                    )?;
                    conns[t] = Some((stream, fr));
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(format!(
                            "timed out accepting peers ({accepted}/{} connected)",
                            plan.shard
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(format!("accept: {e}")),
            }
        }

        // Spawn the per-peer reader/writer pairs.
        let m = plan.nodes;
        let mut senders: Vec<Option<mpsc::Sender<Arc<Vec<u8>>>>> =
            (0..shards).map(|_| None).collect();
        let mut readers = Vec::new();
        let mut writers = Vec::new();
        for (t, conn) in conns.into_iter().enumerate() {
            let Some((stream, fr)) = conn else { continue };
            let (tx, rx) = mpsc::channel::<Arc<Vec<u8>>>();
            senders[t] = Some(tx);
            let wboard = board.clone();
            let wobs = obs.clone();
            let own = plan.shard as u32;
            writers.push(std::thread::spawn(move || {
                writer_loop(stream, rx, own, t, &wboard, &wobs)
            }));
            let rboard = board.clone();
            let rstop = stop.clone();
            let rgrid = sgrid.clone();
            readers.push(std::thread::spawn(move || {
                reader_loop(fr, rgrid, &rboard, &rstop, m, n, t)
            }));
        }
        Ok(Mesh { shard: plan.shard, senders, board, stop, readers, writers })
    }

    /// Send one marker to every peer (after any gradients already
    /// queued — FIFO per stream is the fencing guarantee).
    fn broadcast_marker(&self, phase: MarkerPhase, value: u64) {
        let frame = Arc::new(codec::encode_done(self.shard as u32, phase, value));
        for tx in self.senders.iter().flatten() {
            let _ = tx.send(frame.clone());
        }
    }

    /// Close the mesh: writers flush + say `Bye`, readers drain peers
    /// until their `Bye`. Returns any error any network thread hit.
    fn shutdown(mut self) -> Result<(), String> {
        for tx in self.senders.iter_mut() {
            *tx = None; // closes the channel; writer sends Bye and exits
        }
        for h in self.writers.drain(..) {
            let _ = h.join();
        }
        self.stop.store(true, Ordering::Release);
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        match self.board.error() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

// ------------------------------------------------------------ scheduler glue

/// DCWB's composed round gate on a mesh: in-process barrier →
/// cross-shard round-marker exchange (run by the fence leader while
/// every local worker is parked) → in-process barrier. The two
/// `std::sync::Barrier` waits of the threaded executor become two
/// marker exchanges per round, and the in-shard worker pool composes
/// with them transparently. A mesh failure (or a failed leader ship)
/// poisons the fence, so every local worker fails loudly instead of
/// waiting forever, and a draining worker that happens to win the
/// leader election still performs the marker exchange — the
/// cross-shard protocol survives local failures.
struct MeshGate<'a> {
    fence: PhaseBarrier,
    mesh: &'a Mesh,
    sweeps: usize,
    wait_budget: Duration,
}

impl RoundGate for MeshGate<'_> {
    fn phases(&self) -> usize {
        2 * self.sweeps
    }

    fn serve(
        &self,
        idx: usize,
        on_leader: &dyn Fn() -> Result<(), String>,
    ) -> Result<(), String> {
        let r = (idx / 2) as u64;
        let publish = idx % 2 == 0;
        let me = self.mesh.shard;
        let leader = self.fence.wait()?;
        if leader {
            let exchange = || -> Result<(), String> {
                // leader work (snapshot ship) precedes the marker so
                // FIFO on the report stream keeps Report-after-Snapshot
                on_leader()?;
                let (phase, what) = if publish {
                    (MarkerPhase::RoundPublished, "round publish fence")
                } else {
                    (MarkerPhase::RoundCollected, "round collect fence")
                };
                self.mesh.broadcast_marker(phase, r);
                self.mesh.board.wait_until(self.wait_budget, what, |s| {
                    let col = if publish { &s.published } else { &s.collected };
                    col.iter().enumerate().all(|(t, &v)| t == me || v >= r + 1)
                })
            };
            if let Err(e) = exchange() {
                self.fence.poison(e.clone());
                return Err(e);
            }
        }
        self.fence.wait()?;
        Ok(())
    }

    fn poisoned(&self) -> bool {
        self.fence.is_poisoned()
    }
}

/// Sweep-boundary hooks of a shard run: stream the local η̄ block to
/// the aggregator ([`WireMsg::Snapshot`]) and exchange lockstep
/// markers. `sweep_complete` is always invoked by exactly one worker
/// at a time (a fence leader or the serial baton holder), so the
/// report stream sees frames whole and in order.
struct ShardSweepHooks<'a> {
    mesh: &'a Mesh,
    shard: u32,
    /// Effective pacing for marker purposes (`Free` for DCWB, whose
    /// fences live in [`MeshGate`]).
    pacing: Pacing,
    record: bool,
    report: Option<&'a TcpStream>,
    sweeps: u64,
    wait_budget: Duration,
    obs: Arc<Telemetry>,
}

impl SweepHooks for ShardSweepHooks<'_> {
    fn wants_blocks(&self) -> bool {
        self.record
    }

    fn sweep_start(&self, r: usize) -> Result<(), String> {
        if self.pacing != Pacing::Lockstep {
            return Ok(());
        }
        // my turn once every lower shard finished sweep r and every
        // higher shard finished sweep r−1
        let me = self.shard as usize;
        let r = r as u64;
        self.mesh.board.wait_until(self.wait_budget, "lockstep turn", |s| {
            s.sweeps.iter().enumerate().all(|(t, &done)| {
                if t == me {
                    true
                } else if t < me {
                    done >= r + 1
                } else {
                    done >= r
                }
            })
        })
    }

    fn sweep_complete(&self, r: usize, block: &[f64]) -> Result<(), String> {
        if self.record {
            let mut w = self.report.expect("record_sweeps requires a report stream");
            codec::write_frame(
                &mut w,
                &codec::encode_snapshot(self.shard, r as u64, block),
                Some(&self.obs),
            )?;
        }
        if self.pacing == Pacing::Lockstep {
            self.mesh.broadcast_marker(MarkerPhase::SweepDone, r as u64);
        }
        Ok(())
    }

    fn drain(&self) {
        // A cancelled or failed shard releases peers still waiting on
        // its sweep markers: the board keeps per-shard maxima, so the
        // terminal marker alone satisfies every remaining lockstep
        // turn. (DCWB's round markers are drained phase by phase by
        // each worker's gate ledger instead.)
        if self.pacing == Pacing::Lockstep && self.sweeps > 0 {
            self.mesh.broadcast_marker(MarkerPhase::SweepDone, self.sweeps - 1);
        }
    }
}

fn writer_loop(
    stream: TcpStream,
    rx: mpsc::Receiver<Arc<Vec<u8>>>,
    own_shard: u32,
    peer: usize,
    board: &Board,
    obs: &Telemetry,
) {
    let mut w = &stream;
    loop {
        match rx.recv() {
            Ok(frame) => {
                if let Err(e) = codec::write_frame(&mut w, &frame, Some(obs)) {
                    board.fail(format!("writer to shard {peer}: {e}"));
                    return;
                }
                // drain whatever else is queued before the next block
                while let Ok(next) = rx.try_recv() {
                    if let Err(e) = codec::write_frame(&mut w, &next, Some(obs)) {
                        board.fail(format!("writer to shard {peer}: {e}"));
                        return;
                    }
                }
            }
            Err(_) => {
                // clean shutdown: all senders dropped
                let _ = codec::write_frame(&mut w, &codec::encode_bye(own_shard), Some(obs));
                let _ = stream.shutdown(Shutdown::Write);
                return;
            }
        }
    }
}

fn reader_loop(
    mut fr: FrameReader<TcpStream>,
    sgrid: Arc<ShardedMailboxGrid>,
    board: &Board,
    stop: &AtomicBool,
    m: usize,
    n: usize,
    peer: usize,
) {
    // Armed once the local shard has shut down; any frame from the
    // peer re-arms it, so only a peer that is genuinely *silent* for
    // the whole grace window is declared dead — an actively-sending
    // slow peer is drained for as long as it keeps talking.
    let mut stop_seen: Option<Instant> = None;
    loop {
        match fr.next_frame() {
            Ok(ReadEvent::Msg(WireMsg::Grad { src, stamp, grad })) => {
                stop_seen = None;
                if src as usize >= m || grad.len() != n {
                    board.fail(format!(
                        "shard {peer} sent invalid gradient (src {src}, len {})",
                        grad.len()
                    ));
                    return;
                }
                sgrid.grid.publish(src as usize, stamp, &Arc::new(grad));
            }
            Ok(ReadEvent::Msg(WireMsg::Done { shard, phase, value })) => {
                stop_seen = None;
                board.mark(shard as usize, phase, value);
            }
            Ok(ReadEvent::Msg(WireMsg::Bye { .. })) => return,
            Ok(ReadEvent::Msg(other)) => {
                board.fail(format!("shard {peer} sent unexpected {other:?}"));
                return;
            }
            Ok(ReadEvent::Eof) => {
                board.fail(format!("shard {peer} closed the stream without Bye"));
                return;
            }
            Ok(ReadEvent::Timeout) => {
                if stop.load(Ordering::Acquire) {
                    let first = *stop_seen.get_or_insert_with(Instant::now);
                    if first.elapsed() > DRAIN_GRACE {
                        board.fail(format!(
                            "shard {peer} silent for {DRAIN_GRACE:?} straight after \
                             local shutdown (no Bye)"
                        ));
                        return;
                    }
                }
            }
            Err(e) => {
                board.fail(format!("reader from shard {peer}: {e}"));
                return;
            }
        }
    }
}

// ------------------------------------------------------------ shard run

/// Everything [`run_shard`] needs besides the experiment itself.
pub struct ShardRunOpts {
    pub plan: ShardPlan,
    pub pacing: Pacing,
    /// In-shard worker pool size W (clamped to the local node count):
    /// the shard's local nodes run on W threads of the shared
    /// [`NodeScheduler`], so `--processes P --workers W` scales P×W.
    pub workers: usize,
    /// Stream the local η̄ block to the aggregator after every sweep
    /// (as incremental [`WireMsg::Snapshot`] frames on the `report`
    /// stream) so it can evaluate the full metric trajectory while the
    /// run is in flight. Requires `report`.
    pub record_sweeps: bool,
    /// Pre-bound listening socket for lower-index peers to dial.
    pub listener: TcpListener,
    /// All shard listen addresses, in shard order (own entry included).
    pub peer_addrs: Vec<String>,
    /// Already-connected stream to the aggregating process: per-sweep
    /// [`WireMsg::Snapshot`] frames travel on it during the run, the
    /// final [`WireMsg::Report`] closes it — and [`WireMsg::Cancel`]
    /// frames travel **down** it, tripping `cancel` mid-run. `None`
    /// for a shard nobody aggregates (manual `serve` without
    /// `--report`).
    pub report: Option<TcpStream>,
    /// Cooperative stop handle: trip it locally, or let a collector
    /// trip it remotely via a [`WireMsg::Cancel`] frame on `report`.
    /// The shard winds down through the normal join path and replies
    /// with a well-formed partial [`ShardReport`].
    pub cancel: CancelToken,
    /// Test instrumentation (worker panic injection, forwarded to the
    /// scheduler) — `None` on every production path.
    pub fault_injection: Option<FailPoint>,
}

/// Run this shard's slice of the experiment against the live mesh.
///
/// Iteration indices are assigned deterministically as
/// `k = sweep·m + node` (no cross-process counter), so θ indices and
/// wire stamps are schedule-pure; see the
/// [module docs](crate::exec::net) for what each [`Pacing`] guarantees
/// on top.
pub fn run_shard(cfg: &ExperimentConfig, opts: ShardRunOpts) -> Result<ShardReport, String> {
    cfg.validate()?;
    let ShardRunOpts {
        plan,
        pacing,
        workers,
        record_sweeps,
        listener,
        peer_addrs,
        report,
        cancel,
        fault_injection,
    } = opts;
    if workers == 0 {
        return Err("shard worker pool needs workers >= 1".into());
    }
    if record_sweeps && report.is_none() {
        return Err(
            "record_sweeps streams per-sweep Snapshot frames and therefore \
             needs a report stream (serve: pass --report HOST:PORT)"
                .into(),
        );
    }
    if plan.nodes != cfg.nodes {
        return Err(format!("plan covers {} nodes, config has {}", plan.nodes, cfg.nodes));
    }
    if cfg.faults.drop_prob > 0.0 {
        // Only the simulator has a message-fate model; TCP does not
        // drop frames, so accepting drop_prob here would silently run
        // a lossless experiment labeled as a lossy one.
        return Err(
            "drop_prob > 0 is modeled by the sim executor only; the socket \
             transport delivers reliably (wire-level loss injection is a \
             ROADMAP follow-up)"
                .into(),
        );
    }
    let m = cfg.nodes;
    let n = cfg.support_size();
    let graph = Graph::build(m, cfg.topology);
    if !graph.is_connected() {
        return Err("topology must be connected".into());
    }
    let sync = cfg.algorithm == AlgorithmKind::Dcwb;
    let compensated = cfg.algorithm != AlgorithmKind::A2dwbn;
    let m_theta = if sync { 1 } else { m };
    let sweeps = ((cfg.duration / cfg.activation_interval).round() as usize).max(1);
    let local = plan.local();
    let workers = workers.min(local.len());

    // One registry per shard, keyed by *global* node ids (table sized
    // m): the aggregator merges shard snapshots elementwise, so the
    // disjoint local slices stitch into the full per-node table.
    let obs = Telemetry::shared(m);
    if let Some(cap) = cfg.trace_capacity {
        obs.set_trace_capacity(cap);
    }
    let measures = cfg.measure.build_network(m, cfg.seed);
    // Prevalidate the oracle backend on this thread (the worker pool
    // must not fail after the mesh is committed); this instance also
    // computes the initial exchange below.
    let mut oracle = cfg.backend.build(cfg.samples_per_activation, n)?;
    oracle.attach_obs(obs.clone());
    oracle.set_kernel(cfg.kernel);
    let lambda_max = graph.lambda_max();
    let gamma = cfg.gamma_scale / (lambda_max / cfg.beta);

    // Node state + RNG streams: derived for the whole network exactly
    // as the threaded executor derives them, then only the local block
    // is used — so node i's draws are identical no matter which shard
    // (or worker thread) hosts it.
    let mut root = Rng64::new(cfg.seed ^ 0x5254_4E44);
    let mut node_rngs: Vec<Rng64> = (0..m).map(|i| root.split(i as u64)).collect();
    let node_factors = cfg.faults.node_factors(m, cfg.seed);
    let mut nodes: Vec<WbpNode> =
        local.clone().map(|i| WbpNode::new(n, graph.degree(i))).collect();

    let mut sgrid = ShardedMailboxGrid::new(&graph, n, plan);
    sgrid.attach_obs(obs.clone());
    let sgrid = Arc::new(sgrid);
    let hello = HelloFrame {
        shard: plan.shard as u32,
        shards: plan.shards as u32,
        nodes: m as u32,
        support: n as u32,
        seed: cfg.seed,
        algo: algo_code(cfg.algorithm),
        sweeps: sweeps as u64,
        pacing: pacing.code(),
        digest: config_digest(cfg),
    };
    let total_compute = sweeps as f64 * m as f64 * cfg.compute_time.max(0.0);
    let wait_budget =
        Duration::from_secs_f64(60.0 + 2.0 * cfg.duration + 10.0 * total_compute);
    let mesh = Mesh::establish(
        plan,
        listener,
        &peer_addrs,
        hello,
        sgrid.clone(),
        n,
        wait_budget,
        obs.clone(),
    )?;

    // Cancel listener: the only frames that travel *down* the report
    // stream are Cancel requests from the collector — a tiny reader
    // thread trips the shared token and the workers notice it at their
    // next claim point.
    let stop_listener = Arc::new(AtomicBool::new(false));
    let cancel_listener = match &report {
        Some(stream) => {
            stream
                .set_read_timeout(Some(READ_POLL))
                .map_err(|e| format!("report read timeout: {e}"))?;
            let clone = stream.try_clone().map_err(|e| format!("report clone: {e}"))?;
            let token = cancel.clone();
            let stop = stop_listener.clone();
            let lobs = obs.clone();
            Some(std::thread::spawn(move || {
                let mut fr = FrameReader::new(clone);
                fr.attach_obs(lobs);
                loop {
                    match fr.next_frame() {
                        Ok(ReadEvent::Msg(WireMsg::Cancel)) => token.cancel(),
                        Ok(ReadEvent::Timeout) => {
                            if stop.load(Ordering::Acquire) {
                                return;
                            }
                        }
                        // EOF, unexpected frames, or read errors: the
                        // collector is gone or confused — nothing more
                        // to listen for (a dead collector surfaces as
                        // a write error on the snapshot path instead).
                        _ => return,
                    }
                }
            }))
        }
        None => None,
    };
    let stop_listening = |handle: Option<std::thread::JoinHandle<()>>| {
        stop_listener.store(true, Ordering::Release);
        if let Some(h) = handle {
            let _ = h.join();
        }
    };

    let t0 = Instant::now();

    let mut init_messages = 0u64;
    let mut init_wire = 0u64;
    if !sync {
        // Algorithm 3 line 1 for the local nodes (same draws, in node
        // order, as `exec::initial_exchange` makes over the full set).
        let mut transport = ShardedTransport::new(&sgrid, &mesh.senders);
        let mut theta0 = ThetaSeq::new(m_theta);
        let mut samples = Samples::empty();
        let mut point = vec![0.0; n];
        for (li, i) in local.clone().enumerate() {
            let node = &mut nodes[li];
            node.eval_point(&mut theta0, 0, true, &mut point);
            measures[i].draw_samples_into(
                &mut node_rngs[i],
                cfg.samples_per_activation,
                &mut samples,
            );
            let rows = measures[i].cost_rows(&samples);
            oracle.eval(&point, &rows, cfg.beta, &mut node.own_grad);
            transport.broadcast(i, 0, Arc::new(node.own_grad.clone()));
        }
        init_messages = transport.messages;
        init_wire = transport.wire_messages;
    }
    // Init marker: fences the initial gradients (FIFO) and holds every
    // shard at the start line until the whole mesh is up.
    mesh.broadcast_marker(MarkerPhase::Init, 0);
    let me = plan.shard;
    if let Err(e) = mesh.board.wait_until(wait_budget, "initial exchange", |s| {
        s.init.iter().enumerate().all(|(t, &ok)| t == me || ok)
    }) {
        stop_listening(cancel_listener);
        return Err(e);
    }

    // Hand the local range to the shared scheduler: deterministic
    // iteration claims (k = sweep·m + node — no cross-process counter
    // to race on), the lockstep validation mode running serially
    // across the worker pool (bit parity at any P×W split), and DCWB
    // fenced by the composed MeshGate.
    let order = if !sync && pacing == Pacing::Lockstep {
        ClaimOrder::Serial
    } else {
        ClaimOrder::Deterministic
    };
    let sched = NodeScheduler::new(SchedulerSpec {
        cfg,
        graph: &graph,
        measures: &measures,
        range: local.clone(),
        workers,
        sweeps,
        gamma,
        m_theta,
        sync,
        compensated,
        node_factors: &node_factors,
        cancel: cancel.clone(),
        order,
        cadence_snapshots: false,
        jitter_salt: plan.shard as u64,
        fault_injection,
        obs: Some(obs.clone()),
    });
    let hooks = ShardSweepHooks {
        mesh: &mesh,
        shard: plan.shard as u32,
        pacing: if sync { Pacing::Free } else { pacing },
        record: record_sweeps,
        report: report.as_ref(),
        sweeps: sweeps as u64,
        wait_budget,
        obs: obs.clone(),
    };
    let mesh_gate;
    let local_gate;
    let free_gate;
    let gate: &dyn RoundGate = if sync {
        mesh_gate = MeshGate {
            fence: PhaseBarrier::new(workers),
            mesh: &mesh,
            sweeps,
            wait_budget,
        };
        &mesh_gate
    } else if record_sweeps && order == ClaimOrder::Deterministic {
        // recorded free-pacing runs fence their sweeps locally so the
        // shipped block is a consistent state
        local_gate = LocalGate::new(workers, sweeps);
        &local_gate
    } else {
        // barrier-free end to end; lockstep ships from the serial
        // baton and needs no fence either
        free_gate = FreeGate;
        &free_gate
    };

    let dealt: Vec<(usize, WbpNode, Rng64)> = {
        let mut rng_slots: Vec<Option<Rng64>> =
            node_rngs.into_iter().map(Some).collect();
        local
            .clone()
            .zip(nodes)
            .map(|(i, node)| (i, node, rng_slots[i].take().expect("rng taken once")))
            .collect()
    };
    let per_worker = NodeScheduler::deal_round_robin(dealt, workers);
    let outcome = match sched.run(
        per_worker,
        &|_w| ShardedTransport::new(&sgrid, &mesh.senders),
        gate,
        &hooks,
        &mut || {},
    ) {
        Ok(o) => o,
        Err(e) => {
            stop_listening(cancel_listener);
            return Err(e);
        }
    };
    let window_secs = t0.elapsed().as_secs_f64();

    // Final η̄ at the common θ index every backend reports at — the
    // minimum sweep any worker completed (the full budget unless
    // cancelled).
    let cancelled = cancel.is_cancelled();
    let sweeps_done = outcome.sweeps_done_min;
    let k_final = if sync { sweeps_done } else { sweeps_done * m };
    let mut theta_final = ThetaSeq::new(m_theta);
    let mut point = vec![0.0; n];
    let mut final_etas = vec![0.0; local.len() * n];
    for (li, (_, node)) in outcome.nodes.iter().enumerate() {
        node.eta(&mut theta_final, k_final.max(1), &mut point);
        final_etas[li * n..(li + 1) * n].copy_from_slice(&point);
    }

    let messages = init_messages + outcome.messages;
    let wire_messages = init_wire + outcome.wire_messages;
    if let Err(e) = mesh.shutdown() {
        stop_listening(cancel_listener);
        return Err(e);
    }
    obs.add(Counter::Messages, messages);
    // Snapshot AFTER mesh shutdown: every queued gradient frame has
    // been flushed (writers joined) and every peer's stream drained to
    // its Bye (readers joined), so the per-kind wire tables are
    // complete — `wire_kind_sent(Grad)` equals the legacy
    // `wire_messages` tally exactly. Only the two terminal
    // report-stream frames below post-date the snapshot, by
    // construction.
    let snapshot = obs.snapshot();
    let shard_report = ShardReport {
        shard: plan.shard,
        activations: outcome.activations,
        messages,
        wire_messages,
        rounds: if sync { sweeps_done as u64 } else { 0 },
        sweeps_done: sweeps_done as u64,
        cancelled,
        window_secs,
        final_etas,
    };
    // The terminal frames travel on the same stream, after every
    // streamed Snapshot (FIFO: the aggregator is guaranteed to have
    // seen the whole trajectory once it reads the Report): first the
    // shard's telemetry snapshot, then the Report that closes the
    // stream.
    let mut send_res = Ok(());
    if let Some(stream) = &report {
        let mut w = stream;
        send_res = codec::write_frame(
            &mut w,
            &codec::encode_telemetry(plan.shard as u32, &snapshot),
            Some(&obs),
        )
        .and_then(|()| {
            codec::write_frame(&mut w, &codec::encode_report(&shard_report), Some(&obs))
        });
        if send_res.is_ok() {
            let _ = stream.shutdown(Shutdown::Write);
        }
    }
    stop_listening(cancel_listener);
    send_res?;
    Ok(shard_report)
}

// ------------------------------------------------------------ aggregation

/// Streaming trajectory aggregation: consumes per-sweep
/// [`WireMsg::Snapshot`] blocks *as they arrive*, evaluates each sweep
/// the moment every shard has delivered it (with the exact timestamp
/// formulas the threaded executor uses — which is why a lockstep
/// mesh's series is comparable, bit for bit, to a single-process
/// `SampleCadence::Activations(m)` run), and drops the blocks
/// immediately. Memory is O(network state × shard skew), not
/// O(trajectory) — the paper-scale telemetry path ROADMAP item (m)
/// asked for. [`StreamAggregator::finish`] stitches the final state
/// from the end-of-run [`ShardReport`]s into the one
/// [`ExperimentReport`].
pub struct StreamAggregator {
    cfg: ExperimentConfig,
    plan: ShardPlan,
    graph: Graph,
    measures: Vec<Box<dyn NodeMeasure>>,
    evaluator: MetricsEvaluator,
    sweeps_total: u64,
    /// Scratch: the stitched m×n state of the sweep being evaluated.
    etas: Vec<f64>,
    /// Sweeps with at least one block still missing: sweep → per-shard
    /// slots. Completed sweeps are evaluated and removed on the spot,
    /// so this holds at most the shard skew — and the collector
    /// throttles any shard running [`MAX_SNAPSHOT_LEAD`] sweeps ahead
    /// (TCP backpressure then paces the shard itself), keeping it
    /// bounded even under free pacing with one straggler.
    pending: BTreeMap<u64, Vec<Option<Vec<f64>>>>,
    /// Highest `sweep + 1` delivered per shard (drives the
    /// [`StreamAggregator::lead`] throttle).
    delivered_hi: Vec<u64>,
    /// Next sweep to evaluate (sweeps are evaluated strictly in order,
    /// so the series stays monotone even when shards skew).
    next_sweep: u64,
    saw_snapshot: bool,
    /// Mesh-wide telemetry: elementwise merge of every shard's
    /// end-of-run [`WireMsg::Telemetry`] snapshot. Shards key their
    /// per-node tables by *global* node id (registries are sized m on
    /// every shard), so the merge stitches disjoint slices exactly.
    telemetry: TelemetrySnapshot,
    saw_telemetry: bool,
    /// Activations *delivered* so far (arrival side, not evaluation):
    /// drives the decoupled `progress_every` heartbeat, which must not
    /// stall behind a straggler shard the way the in-order evaluation
    /// loop does.
    acts_delivered: u64,
    /// Multiples of `progress_every` already announced.
    heartbeat_marks: u64,
    dual_series: Series,
    consensus_series: Series,
    spread_series: Series,
    dual_wall: Series,
    t0: Instant,
}

impl StreamAggregator {
    pub fn new(cfg: &ExperimentConfig, shards: usize) -> Result<Self, String> {
        let m = cfg.nodes;
        let n = cfg.support_size();
        let plan = ShardPlan::new(0, shards, m)?;
        let sweeps_total =
            ((cfg.duration / cfg.activation_interval).round() as u64).max(1);
        let graph = Graph::build(m, cfg.topology);
        let measures = cfg.measure.build_network(m, cfg.seed);
        let mut evaluator =
            MetricsEvaluator::new(&graph, &measures, cfg.beta, cfg.eval_samples, cfg.seed);
        evaluator.set_kernel(cfg.kernel);

        let mut dual_series = Series::new("dual_objective");
        let mut consensus_series = Series::new("consensus");
        let mut spread_series = Series::new("primal_spread");
        let mut dual_wall = Series::new("dual_wall");
        let etas = vec![0.0; m * n];
        let (d0, c0, s0) = evaluator.evaluate(&etas, &measures);
        dual_series.push(0.0, d0);
        consensus_series.push(0.0, c0);
        spread_series.push(0.0, s0);
        dual_wall.push(0.0, d0);

        Ok(Self {
            cfg: cfg.clone(),
            plan,
            graph,
            measures,
            evaluator,
            sweeps_total,
            etas,
            pending: BTreeMap::new(),
            delivered_hi: vec![0; shards],
            next_sweep: 0,
            saw_snapshot: false,
            telemetry: TelemetrySnapshot::default(),
            saw_telemetry: false,
            acts_delivered: 0,
            heartbeat_marks: 0,
            dual_series,
            consensus_series,
            spread_series,
            dual_wall,
            t0: Instant::now(),
        })
    }

    /// Feed one streamed block (shard-local η̄ after `sweep`, taken by
    /// value — the decoded frame's allocation is parked, never copied).
    /// Evaluates — and reports to `observer` as [`RunEvent`]s — every
    /// sweep this completes, in order.
    pub fn on_snapshot(
        &mut self,
        shard: usize,
        sweep: u64,
        block: Vec<f64>,
        observer: &mut dyn RunObserver,
    ) -> Result<(), String> {
        let n = self.cfg.support_size();
        if shard >= self.plan.shards {
            return Err(format!("snapshot from shard {shard} of {}", self.plan.shards));
        }
        if sweep >= self.sweeps_total {
            return Err(format!(
                "snapshot for sweep {sweep} beyond the {}-sweep budget",
                self.sweeps_total
            ));
        }
        let want = self.plan.range(shard).len() * n;
        if block.len() != want {
            return Err(format!(
                "shard {shard} snapshot carries {} values, expected {want}",
                block.len()
            ));
        }
        if sweep < self.next_sweep {
            return Err(format!("shard {shard} re-sent already-evaluated sweep {sweep}"));
        }
        observer.on_event(&RunEvent::ShardSnapshot { shard, sweep });
        let shards = self.plan.shards;
        let slots =
            self.pending.entry(sweep).or_insert_with(|| vec![None; shards]);
        if slots[shard].is_some() {
            return Err(format!("shard {shard} sent sweep {sweep} twice"));
        }
        slots[shard] = Some(block);
        self.delivered_hi[shard] = self.delivered_hi[shard].max(sweep + 1);

        // Arrival-side heartbeat: when `progress_every` is set, count
        // activations as blocks *arrive* and announce each crossed
        // multiple immediately — decoupled from the strictly-in-order
        // evaluation loop below, which a single straggler shard stalls.
        self.acts_delivered += self.plan.range(shard).len() as u64;
        if let Some(every) = self.cfg.progress_every {
            while (self.heartbeat_marks + 1) * every <= self.acts_delivered {
                self.heartbeat_marks += 1;
                observer.on_event(&RunEvent::Progress {
                    activations: self.heartbeat_marks * every,
                    rounds: 0,
                });
            }
        }

        // Evaluate every now-complete sweep in order, dropping blocks.
        while let Some(slots) = self.pending.get(&self.next_sweep) {
            if slots.iter().any(|s| s.is_none()) {
                break;
            }
            let slots = self.pending.remove(&self.next_sweep).unwrap();
            for (s, blk) in slots.iter().enumerate() {
                let range = self.plan.range(s);
                self.etas[range.start * n..range.end * n]
                    .copy_from_slice(blk.as_ref().unwrap());
            }
            let (d, c, sp) = self.evaluator.evaluate(&self.etas, &self.measures);
            let r = self.next_sweep;
            let m = self.cfg.nodes as u64;
            let acts = (r + 1) * m;
            let t = (acts as f64 / m as f64 * self.cfg.activation_interval)
                .min(self.cfg.duration);
            self.dual_series.push(t, d);
            self.consensus_series.push(t, c);
            self.spread_series.push(t, sp);
            observer.on_event(&RunEvent::MetricSample {
                t,
                wall: self.t0.elapsed().as_secs_f64(),
                dual: d,
                consensus: c,
                spread: sp,
            });
            // Eval-coupled progress only when no decoupled cadence was
            // asked for — otherwise the arrival-side heartbeat above
            // owns the Progress stream.
            if self.cfg.progress_every.is_none() {
                observer.on_event(&RunEvent::Progress {
                    activations: acts,
                    rounds: if self.cfg.algorithm == AlgorithmKind::Dcwb {
                        r + 1
                    } else {
                        0
                    },
                });
            }
            self.next_sweep += 1;
        }
        self.saw_snapshot = true;
        Ok(())
    }

    /// How many sweeps `shard` has delivered beyond the next one to be
    /// evaluated — the collector stops draining a stream whose shard
    /// leads by [`MAX_SNAPSHOT_LEAD`], letting TCP backpressure pace
    /// the shard and keeping `pending` bounded under free-pacing skew.
    fn lead(&self, shard: usize) -> u64 {
        self.delivered_hi[shard].saturating_sub(self.next_sweep)
    }

    /// Merge one shard's end-of-run telemetry snapshot into the
    /// mesh-wide tables. Counters and wire tallies add; per-node tables
    /// stitch exactly because every shard keys them by global node id.
    pub fn on_telemetry(
        &mut self,
        shard: usize,
        snapshot: &TelemetrySnapshot,
    ) -> Result<(), String> {
        if shard >= self.plan.shards {
            return Err(format!("telemetry from shard {shard} of {}", self.plan.shards));
        }
        self.telemetry.merge(snapshot);
        self.saw_telemetry = true;
        Ok(())
    }

    /// Stitch the end-of-run reports into the final
    /// [`ExperimentReport`]. Fails if any streamed trajectory is
    /// incomplete (a shard recorded sweeps the others never delivered)
    /// — unless the run was cancelled, in which case the partial
    /// trajectory is honest by construction: the series covers the
    /// sweeps every shard delivered, the final point sits at the
    /// virtual time of the least-advanced shard, and
    /// [`ExperimentReport::cancelled`] is set. That final point
    /// stitches each shard's state at its *own* stop index (see
    /// [`ShardReport::final_etas`]) — a true snapshot of where the
    /// network halted, not a synchronized iterate.
    pub fn finish(mut self, mut reports: Vec<ShardReport>) -> Result<ExperimentReport, String> {
        let shards = self.plan.shards;
        let n = self.cfg.support_size();
        reports.sort_by_key(|r| r.shard);
        if reports.len() != shards
            || reports.iter().enumerate().any(|(s, r)| r.shard != s)
        {
            let got: Vec<usize> = reports.iter().map(|r| r.shard).collect();
            return Err(format!("need one report per shard 0..{shards}, got {got:?}"));
        }
        for (s, r) in reports.iter().enumerate() {
            let want = self.plan.range(s).len() * n;
            if r.final_etas.len() != want {
                return Err(format!(
                    "shard {s} reported {} final values, expected {want}",
                    r.final_etas.len()
                ));
            }
        }
        let cancelled = reports.iter().any(|r| r.cancelled);
        if self.saw_snapshot
            && !cancelled
            && (self.next_sweep < self.sweeps_total || !self.pending.is_empty())
        {
            return Err(format!(
                "sweep {} missing from some shard's trajectory stream",
                self.next_sweep
            ));
        }

        for (s, r) in reports.iter().enumerate() {
            let range = self.plan.range(s);
            self.etas[range.start * n..range.end * n].copy_from_slice(&r.final_etas);
        }
        let (d, c, sp) = self.evaluator.evaluate(&self.etas, &self.measures);
        // Uncancelled runs report their final state at the horizon;
        // cancelled ones at the virtual time of the least-advanced
        // shard, which is ≥ the last evaluated sweep's timestamp (only
        // fully delivered sweeps are evaluated), so the partial series
        // stays monotone.
        let min_sweeps = reports.iter().map(|r| r.sweeps_done).min().unwrap_or(0);
        let t_end = if cancelled {
            (min_sweeps as f64 * self.cfg.activation_interval).min(self.cfg.duration)
        } else {
            self.cfg.duration
        };
        self.dual_series.push(t_end, d);
        self.consensus_series.push(t_end, c);
        self.spread_series.push(t_end, sp);
        let window = reports.iter().map(|r| r.window_secs).fold(0.0, f64::max);
        self.dual_wall.push(window, d);

        let sync = self.cfg.algorithm == AlgorithmKind::Dcwb;
        let budget: u64 = reports.iter().map(|r| r.activations).sum();
        let telemetry = if self.saw_telemetry {
            self.telemetry
        } else {
            // Compat path ([`aggregate_reports`]: end-of-run reports
            // only, no streams and hence no Telemetry frames) —
            // synthesize the one table downstream readers rely on,
            // gradient frames sent (wire kind 2 = Grad), from the
            // summed ShardReport tallies, so
            // [`ExperimentReport::wire_messages`] stays exact.
            let mut wire = vec![[0u64; 4]; crate::obs::WIRE_KINDS];
            wire[2][0] = reports.iter().map(|r| r.wire_messages).sum();
            TelemetrySnapshot { wire, ..TelemetrySnapshot::default() }
        };
        let rounds = if sync {
            if cancelled {
                min_sweeps
            } else {
                self.sweeps_total
            }
        } else {
            0
        };
        Ok(ExperimentReport {
            tag: mesh_tag(&self.cfg, shards),
            algorithm: self.cfg.algorithm,
            dual_objective: self.dual_series,
            consensus: self.consensus_series,
            primal_spread: self.spread_series,
            dual_wall: self.dual_wall,
            activations: budget,
            rounds,
            messages: reports.iter().map(|r| r.messages).sum(),
            telemetry,
            events: budget,
            lambda_max: self.graph.lambda_max(),
            wall_seconds: 0.0,
            barycenter: self.evaluator.barycenter(),
            cancelled,
        })
    }
}

/// Emit the observer-contract bookends for a mesh run: `Started` plus
/// the zero-state sample before the shards spin up, and the final
/// sample plus `Finished(RunTotals)` mirroring the aggregated report —
/// so a [`TrajectorySink`] (or any observer gating on
/// the terminal event) works on the net backend like it does on
/// `Sim`/`Threads`: the stream reproduces the report's virtual-time
/// series (`dual_objective`/`consensus`/`primal_spread`) bit for bit.
/// `MetricSample.wall` is the *aggregator's* clock (arrival time of
/// each completed sweep) and is stream-local: the report's `dual_wall`
/// keeps only the zero point and the shard-side run window, so a sink's
/// wall series is an arrival-time view, not the report's.
///
/// [`TrajectorySink`]: crate::coordinator::TrajectorySink
fn emit_started(
    cfg: &ExperimentConfig,
    shards: usize,
    agg: &StreamAggregator,
    observer: &mut dyn RunObserver,
) {
    observer.on_event(&RunEvent::Started {
        tag: mesh_tag(cfg, shards),
        algorithm: cfg.algorithm,
        nodes: cfg.nodes,
        support: cfg.support_size(),
    });
    // the aggregator evaluated the zero state at construction
    observer.on_event(&RunEvent::MetricSample {
        t: 0.0,
        wall: 0.0,
        dual: agg.dual_series.points[0].1,
        consensus: agg.consensus_series.points[0].1,
        spread: agg.spread_series.points[0].1,
    });
}

fn emit_finished(
    report: &ExperimentReport,
    agg_clock: Instant,
    observer: &mut dyn RunObserver,
) {
    // The final stitched sample (pushed by StreamAggregator::finish).
    // Its wall stays on the aggregator's arrival clock — the same one
    // every per-sweep sample used — so the streamed wall axis is
    // monotone (the report's shard-side run window would not be).
    if let (Some(&(t, dual)), Some(&(_, consensus)), Some(&(_, spread))) = (
        report.dual_objective.points.last(),
        report.consensus.points.last(),
        report.primal_spread.points.last(),
    ) {
        let wall = agg_clock.elapsed().as_secs_f64();
        observer.on_event(&RunEvent::MetricSample { t, wall, dual, consensus, spread });
    }
    observer.on_event(&RunEvent::Finished(crate::coordinator::RunTotals {
        tag: report.tag.clone(),
        algorithm: report.algorithm,
        activations: report.activations,
        rounds: report.rounds,
        messages: report.messages,
        events: report.events,
        lambda_max: report.lambda_max,
        barycenter: report.barycenter.clone(),
        cancelled: report.cancelled,
        telemetry: report.telemetry.clone(),
    }));
}

/// Aggregate end-of-run reports with no streamed trajectory (zero
/// state + final state only) — the compat path for callers holding
/// already-collected [`ShardReport`]s; streamed runs go through
/// [`StreamAggregator`] / [`collect_shard_streams`].
pub fn aggregate_reports(
    cfg: &ExperimentConfig,
    shards: usize,
    reports: Vec<ShardReport>,
) -> Result<ExperimentReport, String> {
    StreamAggregator::new(cfg, shards)?.finish(reports)
}

// ------------------------------------------------------------ mesh runners

/// Shape of a mesh run: shard count P, per-shard worker pool W,
/// pacing, trajectory recording, and a cooperative stop handle. Built
/// fluently: `MeshOpts::new(2).workers(2).pacing(Pacing::Lockstep)`.
#[derive(Clone)]
pub struct MeshOpts {
    /// Shard (process) count P.
    pub shards: usize,
    /// In-shard worker pool size W — the mesh runs P×W workers total.
    pub workers: usize,
    pub pacing: Pacing,
    pub record_sweeps: bool,
    /// Trip it (from an observer callback or any thread) to stop the
    /// whole mesh cooperatively: the collector sends a
    /// [`WireMsg::Cancel`] frame down every shard's report stream and
    /// the run returns a well-formed partial report with
    /// [`ExperimentReport::cancelled`] set.
    pub cancel: CancelToken,
}

impl MeshOpts {
    pub fn new(shards: usize) -> Self {
        Self {
            shards,
            workers: 1,
            pacing: Pacing::Free,
            record_sweeps: false,
            cancel: CancelToken::new(),
        }
    }

    pub fn workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn pacing(mut self, p: Pacing) -> Self {
        self.pacing = p;
        self
    }

    pub fn record_sweeps(mut self, record: bool) -> Self {
        self.record_sweeps = record;
        self
    }

    pub fn cancel(mut self, token: CancelToken) -> Self {
        self.cancel = token;
        self
    }
}

/// Run a full sharded experiment **in one process**: every shard on
/// its own thread, but with its own sockets — the complete wire path
/// (codec, reader/writer threads, markers, streamed Snapshot frames,
/// Cancel frames) minus process isolation. This is the harness the
/// integration tests and benches use; the CLI's `speedup --processes`
/// uses [`run_mesh_processes`] for the real thing.
pub fn run_mesh_threads(
    cfg: &ExperimentConfig,
    opts: &MeshOpts,
) -> Result<ExperimentReport, String> {
    run_mesh_threads_with(cfg, opts, &mut |_: &RunEvent| {})
}

/// [`run_mesh_threads`] with a live [`RunObserver`]: shard snapshot
/// arrivals and the evaluated per-sweep metric samples stream to
/// `observer` while the mesh runs.
pub fn run_mesh_threads_with(
    cfg: &ExperimentConfig,
    opts: &MeshOpts,
    observer: &mut dyn RunObserver,
) -> Result<ExperimentReport, String> {
    let t_all = Instant::now();
    let shards = opts.shards;
    let _ = ShardPlan::new(0, shards, cfg.nodes)?;
    let mut agg = StreamAggregator::new(cfg, shards)?;
    emit_started(cfg, shards, &agg, observer);
    let mut listeners = Vec::with_capacity(shards);
    let mut addrs = Vec::with_capacity(shards);
    for _ in 0..shards {
        let l = TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind: {e}"))?;
        addrs.push(l.local_addr().map_err(|e| format!("local_addr: {e}"))?.to_string());
        listeners.push(l);
    }
    let report_listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind report socket: {e}"))?;
    let report_addr = report_listener
        .local_addr()
        .map_err(|e| format!("local_addr: {e}"))?
        .to_string();

    let sweeps = ((cfg.duration / cfg.activation_interval).round() as usize).max(1);
    let total_compute = sweeps as f64 * cfg.nodes as f64 * cfg.compute_time.max(0.0);
    let deadline = Instant::now()
        + Duration::from_secs_f64(120.0 + 2.0 * cfg.duration + 10.0 * total_compute);

    // The aggregating collector runs on this thread, concurrently with
    // the shard threads — streamed snapshots are evaluated while the
    // mesh is still sweeping.
    let (collected, shard_results) = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shards);
        for (s, listener) in listeners.into_iter().enumerate() {
            let addrs = addrs.clone();
            let report_addr = report_addr.clone();
            let plan = ShardPlan { shard: s, shards, nodes: cfg.nodes };
            let opts = opts.clone();
            handles.push(scope.spawn(move || -> Result<ShardReport, String> {
                // connect the report stream before running, so a shard
                // that fails is seen as an EOF by the collector instead
                // of an endless accept wait
                let report = TcpStream::connect(&report_addr)
                    .map_err(|e| format!("shard {s}: report connect: {e}"))?;
                run_shard(
                    cfg,
                    ShardRunOpts {
                        plan,
                        pacing: opts.pacing,
                        workers: opts.workers,
                        record_sweeps: opts.record_sweeps,
                        listener,
                        peer_addrs: addrs,
                        report: Some(report),
                        // each shard gets its own token: cancellation
                        // reaches it through the Cancel frame, exactly
                        // like a real multi-process mesh
                        cancel: CancelToken::new(),
                        fault_injection: None,
                    },
                )
            }));
        }
        let collected = collect_shard_streams(
            &report_listener,
            shards,
            &mut agg,
            deadline,
            &mut || Ok(()),
            observer,
            &opts.cancel,
        );
        let shard_results: Vec<Result<ShardReport, String>> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("shard thread panicked".into())))
            .collect();
        (collected, shard_results)
    });
    // A shard's own error is the root cause — prefer it over the
    // collector's (usually derivative) stream error.
    for r in &shard_results {
        if let Err(e) = r {
            return Err(e.clone());
        }
    }
    let reports = collected?;
    let agg_clock = agg.t0;
    let mut report = agg.finish(reports)?;
    report.wall_seconds = t_all.elapsed().as_secs_f64();
    emit_finished(&report, agg_clock, observer);
    Ok(report)
}

/// Serialize `cfg` back into the CLI flags `serve` re-parses, so child
/// shard processes reconstruct the **identical** experiment (every
/// float formatted with Rust's shortest-roundtrip `Display`, which
/// re-parses bit-exactly).
pub fn experiment_args(cfg: &ExperimentConfig) -> Result<Vec<String>, String> {
    if !matches!(cfg.backend, OracleBackendSpec::Native) {
        return Err("multi-process meshes support the native oracle backend only".into());
    }
    if let crate::graph::TopologySpec::ErdosRenyi { seed, .. } = cfg.topology {
        if seed != cfg.seed {
            return Err(
                "er topology carries a seed different from cfg.seed; \
                 child shards could not rebuild the same graph"
                    .into(),
            );
        }
    }
    fn push(a: &mut Vec<String>, k: &str, v: String) {
        a.push(format!("--{k}"));
        a.push(v);
    }
    let mut a: Vec<String> = Vec::new();
    match &cfg.measure {
        MeasureSpec::Gaussian { n } => push(&mut a, "support", n.to_string()),
        MeasureSpec::Digits { digit, side, idx_path } => {
            a.push("--mnist".into());
            push(&mut a, "digit", digit.to_string());
            push(&mut a, "side", side.to_string());
            if let Some(p) = idx_path {
                push(&mut a, "idx-path", p.clone());
            }
        }
    }
    push(&mut a, "nodes", cfg.nodes.to_string());
    push(&mut a, "seed", cfg.seed.to_string());
    push(&mut a, "topology", cfg.topology.cli_string());
    push(&mut a, "algorithm", cfg.algorithm.name().to_string());
    push(&mut a, "beta", cfg.beta.to_string());
    push(&mut a, "gamma-scale", cfg.gamma_scale.to_string());
    push(&mut a, "samples", cfg.samples_per_activation.to_string());
    push(&mut a, "eval-samples", cfg.eval_samples.to_string());
    push(&mut a, "duration", cfg.duration.to_string());
    push(&mut a, "activation-interval", cfg.activation_interval.to_string());
    push(&mut a, "metric-interval", cfg.metric_interval.to_string());
    push(&mut a, "compute-time", cfg.compute_time.to_string());
    push(&mut a, "straggler-fraction", cfg.faults.straggler_fraction.to_string());
    push(&mut a, "straggler-slowdown", cfg.faults.straggler_slowdown.to_string());
    push(&mut a, "drop-prob", cfg.faults.drop_prob.to_string());
    if cfg.diag == crate::algo::wbp::DiagCoef::PaperLiteral {
        a.push("--paper-literal-diag".into());
    }
    if cfg.kernel != crate::kernel::KernelImpl::Scalar {
        push(&mut a, "kernel", cfg.kernel.name().to_string());
    }
    if let Some(cap) = cfg.trace_capacity {
        push(&mut a, "trace-capacity", cap.to_string());
    }
    Ok(a)
}

/// Spawn `shards` child `serve` processes (`exe` must be a binary
/// whose `serve` subcommand reaches [`serve_main`] — the `a2dwb` CLI,
/// or a bench binary that forwards), collect their reports over a
/// local TCP socket, and aggregate.
///
/// Free loopback ports are discovered by binding-then-releasing, so a
/// hostile process racing for ports can make a child fail to bind; the
/// child's error is inherited on stderr and surfaces here as a failed
/// report collection.
pub fn run_mesh_processes(
    cfg: &ExperimentConfig,
    exe: &Path,
    opts: &MeshOpts,
) -> Result<ExperimentReport, String> {
    run_mesh_processes_with(cfg, exe, opts, &mut |_: &RunEvent| {})
}

/// [`run_mesh_processes`] with a live [`RunObserver`] fed from the
/// streamed Snapshot frames the child shard processes ship while they
/// run.
pub fn run_mesh_processes_with(
    cfg: &ExperimentConfig,
    exe: &Path,
    opts: &MeshOpts,
    observer: &mut dyn RunObserver,
) -> Result<ExperimentReport, String> {
    let t_all = Instant::now();
    let shards = opts.shards;
    let _ = ShardPlan::new(0, shards, cfg.nodes)?;
    let base_args = experiment_args(cfg)?;
    let mut agg = StreamAggregator::new(cfg, shards)?;
    emit_started(cfg, shards, &agg, observer);

    // Bind the report socket BEFORE probing shard ports: it stays
    // bound, so it can never be handed one of the just-released probe
    // ports a child was told to --listen on.
    let report_listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind report socket: {e}"))?;
    let report_addr = report_listener
        .local_addr()
        .map_err(|e| format!("local_addr: {e}"))?
        .to_string();
    let mut addrs = Vec::with_capacity(shards);
    {
        let mut probes = Vec::with_capacity(shards);
        for _ in 0..shards {
            let l = TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind: {e}"))?;
            addrs.push(l.local_addr().map_err(|e| format!("local_addr: {e}"))?.to_string());
            probes.push(l);
        } // probes drop here, releasing the ports for the children
    }

    let mut children = Vec::with_capacity(shards);
    for s in 0..shards {
        let mut cmd = std::process::Command::new(exe);
        cmd.arg("serve")
            .arg("--shard")
            .arg(format!("{s}/{shards}"))
            .arg("--listen")
            .arg(&addrs[s])
            .arg("--peers")
            .arg(addrs.join(","))
            .arg("--pacing")
            .arg(opts.pacing.name())
            .arg("--workers")
            .arg(opts.workers.to_string())
            .arg("--report")
            .arg(&report_addr);
        if opts.record_sweeps {
            cmd.arg("--record-sweeps");
        }
        cmd.args(&base_args).stdin(std::process::Stdio::null());
        children.push(
            cmd.spawn()
                .map_err(|e| format!("spawning shard {s} ({}): {e}", exe.display()))?,
        );
    }

    let kill_all = |children: &mut Vec<std::process::Child>| {
        for c in children.iter_mut() {
            let _ = c.kill();
            let _ = c.wait();
        }
    };

    let sweeps = ((cfg.duration / cfg.activation_interval).round() as usize).max(1);
    let total_compute = sweeps as f64 * cfg.nodes as f64 * cfg.compute_time.max(0.0);
    let deadline = Instant::now()
        + Duration::from_secs_f64(120.0 + 2.0 * cfg.duration + 10.0 * total_compute);
    let collected = {
        // fail fast if any child dies before reporting
        let children = &mut children;
        collect_shard_streams(
            &report_listener,
            shards,
            &mut agg,
            deadline,
            &mut || {
                for (s, c) in children.iter_mut().enumerate() {
                    if let Ok(Some(status)) = c.try_wait() {
                        if !status.success() {
                            return Err(format!("shard {s} exited with {status}"));
                        }
                    }
                }
                Ok(())
            },
            observer,
            &opts.cancel,
        )
    };
    let reports = match collected {
        Ok(r) => r,
        Err(e) => {
            kill_all(&mut children);
            return Err(e);
        }
    };
    for (s, mut c) in children.into_iter().enumerate() {
        let status = c.wait().map_err(|e| format!("waiting for shard {s}: {e}"))?;
        if !status.success() {
            return Err(format!("shard {s} exited with {status}"));
        }
    }
    let agg_clock = agg.t0;
    let mut report = agg.finish(reports)?;
    report.wall_seconds = t_all.elapsed().as_secs_f64();
    emit_finished(&report, agg_clock, observer);
    Ok(report)
}

/// Resumable non-blocking frame write: push as many of
/// `frame[progress..]` bytes as the socket accepts right now and
/// return the new progress. Never blocks and never restarts from the
/// beginning — a partially sent frame must be *continued*, not resent,
/// or the receiver's framing desyncs. On a fatal error the frame is
/// abandoned (progress jumps to `frame.len()`): the stream is broken
/// anyway and the caller's collection loop surfaces that separately.
fn push_frame_bytes(stream: &TcpStream, frame: &[u8], progress: usize) -> usize {
    use std::io::Write;
    let mut sent = progress;
    let mut w = stream;
    while sent < frame.len() {
        match w.write(&frame[sent..]) {
            Ok(0) => return frame.len(), // closed: give up
            Ok(k) => sent += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return sent,
            Err(_) => return frame.len(), // broken stream: give up
        }
    }
    sent
}

/// Accept `shards` report-stream connections on `listener` and
/// multiplex them until every shard has delivered its terminal
/// [`WireMsg::Report`]: interleaved [`WireMsg::Snapshot`] frames are
/// fed to `agg` **as they arrive** (each completed sweep is evaluated
/// and its blocks dropped on the spot — nothing is rebuilt at the
/// end), with arrival/sample events streamed to `observer`. `poll`
/// runs on every pass (busy or idle) so callers can watch for dead
/// children or trip time-based aborts. When `cancel` trips, one
/// [`WireMsg::Cancel`] frame is written down every live stream (and
/// any stream accepted later) — the cooperative stop that retires the
/// old collector-teardown-only cancellation — and collection continues
/// until every shard delivers its partial Report. Shared by
/// [`run_mesh_threads_with`], [`run_mesh_processes_with`], and the
/// `a2dwb join` subcommand (manual multi-box orchestration).
pub fn collect_shard_streams(
    listener: &TcpListener,
    shards: usize,
    agg: &mut StreamAggregator,
    deadline: Instant,
    poll: &mut dyn FnMut() -> Result<(), String>,
    observer: &mut dyn RunObserver,
    cancel: &CancelToken,
) -> Result<Vec<ShardReport>, String> {
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("report socket nonblocking: {e}"))?;
    // (reader, report-received, observed shard id, cancel-frame send
    // progress) per accepted stream; non-blocking reads keep every
    // stream draining concurrently, so a shard's snapshot backlog can
    // never stall a peer behind a full socket buffer — except when
    // that shard runs MAX_SNAPSHOT_LEAD sweeps ahead of the slowest
    // one, where we deliberately stop reading it (TCP backpressure
    // then paces the shard) so `pending` stays bounded under
    // free-pacing skew.
    let mut streams: Vec<(FrameReader<TcpStream>, bool, Option<usize>, Option<usize>)> =
        Vec::with_capacity(shards);
    let mut reports: Vec<ShardReport> = Vec::with_capacity(shards);
    let cancel_frame = codec::encode_cancel();
    while reports.len() < shards {
        let mut advanced = false;
        // poll runs on EVERY pass, not just idle ones: it is how
        // callers watch dead children and trip time-based cancellation
        // (`join --cancel-after`), and a mesh streaming snapshots
        // steadily would otherwise starve it indefinitely
        poll()?;
        if streams.len() < shards {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream
                        .set_nonblocking(true)
                        .map_err(|e| format!("report stream: {e}"))?;
                    streams.push((FrameReader::new(stream), false, None, None));
                    advanced = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(format!("report accept: {e}")),
            }
        }
        if cancel.is_cancelled() {
            // Push the Cancel frame down every live stream, resuming
            // partial writes across passes (a half-sent frame must be
            // continued, never restarted, or the shard's reader
            // desyncs). A shard that is already reporting needs none.
            for (fr, done, _, cancel_progress) in streams.iter_mut() {
                let sent = cancel_progress.unwrap_or(0);
                if !*done && sent < cancel_frame.len() {
                    *cancel_progress =
                        Some(push_frame_bytes(fr.get_ref(), &cancel_frame, sent));
                }
            }
        }
        // The lead throttle bounds memory while the mesh runs; once a
        // cancel is in flight it must lift — a cancelled straggler will
        // never complete the sweeps the fast shard is ahead by, so a
        // still-throttled stream would starve its own Report forever.
        let throttled = |lead: u64| !cancel.is_cancelled() && lead >= MAX_SNAPSHOT_LEAD;
        for (fr, done, conn_shard, _) in streams.iter_mut() {
            if *done {
                continue;
            }
            if let Some(s) = *conn_shard {
                if throttled(agg.lead(s)) {
                    continue; // throttled: let the slowest shard catch up
                }
            }
            loop {
                match fr.next_frame() {
                    Ok(ReadEvent::Msg(WireMsg::Snapshot { shard, sweep, etas })) => {
                        *conn_shard = Some(shard as usize);
                        agg.on_snapshot(shard as usize, sweep, etas, observer)?;
                        advanced = true;
                        if throttled(agg.lead(shard as usize)) {
                            break;
                        }
                    }
                    Ok(ReadEvent::Msg(WireMsg::Telemetry { shard, snapshot })) => {
                        *conn_shard = Some(shard as usize);
                        agg.on_telemetry(shard as usize, &snapshot)?;
                        advanced = true;
                    }
                    Ok(ReadEvent::Msg(WireMsg::Report(r))) => {
                        reports.push(r);
                        *done = true;
                        advanced = true;
                        break;
                    }
                    Ok(ReadEvent::Timeout) => break,
                    Ok(ReadEvent::Eof) => {
                        return Err(
                            "shard stream closed before its Report frame".to_string()
                        )
                    }
                    Ok(ReadEvent::Msg(other)) => {
                        return Err(format!(
                            "expected Snapshot/Telemetry/Report on the report stream, got {other:?}"
                        ))
                    }
                    Err(e) => return Err(format!("reading shard stream: {e}")),
                }
            }
        }
        if !advanced {
            if Instant::now() >= deadline {
                return Err(format!(
                    "timed out waiting for shard reports ({}/{shards})",
                    reports.len()
                ));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    Ok(reports)
}

/// CLI flags the `serve` subcommand understands on top of
/// [`ExperimentConfig::CLI_FLAGS`].
pub const SERVE_FLAGS: &[&str] =
    &["shard", "listen", "peers", "pacing", "report", "record-sweeps"];

/// Body of the `serve` subcommand (also reachable from bench binaries
/// so `cargo bench` can fan out over real processes): parse the shard
/// plan + experiment flags, dial the `--report HOST:PORT` aggregator
/// (if given) up front — per-sweep Snapshot frames stream on that
/// connection while the shard runs, the terminal Report frame closes
/// it — then run the shard.
pub fn serve_main(args: &crate::cli::Args) -> Result<(), String> {
    let known: Vec<&str> = ExperimentConfig::CLI_FLAGS
        .iter()
        .chain(SERVE_FLAGS.iter())
        .copied()
        .collect();
    args.reject_unknown(&known)?;
    let cfg = ExperimentConfig::from_cli_args(args, args.has_flag("mnist"))?;
    let plan = ShardPlan::parse(&args.get_str("shard", "0/1"), cfg.nodes)?;
    let listen = args.get_str("listen", "127.0.0.1:0");
    let listener =
        TcpListener::bind(&listen).map_err(|e| format!("binding {listen}: {e}"))?;
    let own_addr = listener
        .local_addr()
        .map_err(|e| format!("local_addr: {e}"))?
        .to_string();
    let mut peer_addrs: Vec<String> = args
        .get_str("peers", "")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if peer_addrs.is_empty() && plan.shards == 1 {
        peer_addrs = vec![own_addr.clone()];
    }
    let pacing = Pacing::parse(&args.get_str("pacing", "free"))?;
    // In-shard worker pool size: `--workers W` (the same flag the
    // threaded executor uses; `--processes P --workers W` runs P×W).
    let workers = args.get("workers", 1usize)?;
    // Dial the aggregator with retry: operators may start the `serve`
    // shards before `a2dwb join` is listening (a valid order when the
    // report connection was only opened at end-of-run), so keep trying
    // for the same window the run itself is given rather than dying on
    // the first refusal.
    let report_stream = match args.get_opt("report") {
        Some(addr) => {
            let sweeps = ((cfg.duration / cfg.activation_interval).round()).max(1.0);
            let total_compute = sweeps * cfg.nodes as f64 * cfg.compute_time.max(0.0);
            let window =
                Duration::from_secs_f64(60.0 + 2.0 * cfg.duration + 10.0 * total_compute);
            Some(dial_retry(addr, Instant::now() + window)?)
        }
        None => None,
    };
    eprintln!(
        "shard {}/{} listening on {own_addr} ({} pacing, {} workers, {} on {})",
        plan.shard,
        plan.shards,
        pacing.name(),
        workers,
        cfg.algorithm.name(),
        cfg.topology.name(),
    );
    let report = run_shard(
        &cfg,
        ShardRunOpts {
            plan,
            pacing,
            workers,
            record_sweeps: args.has_flag("record-sweeps"),
            listener,
            peer_addrs,
            report: report_stream,
            cancel: CancelToken::new(),
            fault_injection: None,
        },
    )?;
    println!(
        "SHARD {}/{} activations={} messages={} wire_messages={} window={:.3}s{}",
        report.shard,
        plan.shards,
        report.activations,
        report.messages,
        report.wire_messages,
        report.window_secs,
        if report.cancelled { " cancelled=true" } else { "" },
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TopologySpec;

    #[test]
    fn sharded_grid_fanout_dedupes_peer_shards() {
        // complete graph on 6 nodes, 3 shards of 2: every node has
        // neighbors in both other shards but each peer appears once
        let graph = Graph::build(6, TopologySpec::Complete);
        let plan = ShardPlan::new(1, 3, 6).unwrap();
        let sg = ShardedMailboxGrid::new(&graph, 4, plan);
        assert_eq!(sg.fanout(2), &[0, 2]);
        assert_eq!(sg.fanout(3), &[0, 2]);
        // cycle: shard 1 of 3 on 6 nodes owns {2, 3}; node 2 touches
        // node 1 (shard 0) only, node 3 touches node 4 (shard 2) only
        let cyc = Graph::build(6, TopologySpec::Cycle);
        let sg = ShardedMailboxGrid::new(&cyc, 4, plan);
        assert_eq!(sg.fanout(2), &[0]);
        assert_eq!(sg.fanout(3), &[2]);
    }

    #[test]
    fn experiment_args_roundtrip_through_cli() {
        let mut cfg = ExperimentConfig::gaussian_default();
        cfg.nodes = 12;
        cfg.seed = 7;
        cfg.beta = 0.037;
        cfg.duration = 2.5;
        cfg.compute_time = 0.00025;
        cfg.faults.straggler_fraction = 0.25;
        cfg.faults.straggler_slowdown = 3.0;
        cfg.kernel = crate::kernel::KernelImpl::Wide;
        cfg.trace_capacity = Some(4096);
        let flags = experiment_args(&cfg).unwrap();
        let parsed = crate::cli::Args::parse(flags).unwrap();
        let back = ExperimentConfig::from_cli_args(&parsed, parsed.has_flag("mnist")).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
    }

    #[test]
    fn experiment_args_rejects_pjrt() {
        let cfg = ExperimentConfig {
            backend: OracleBackendSpec::Pjrt { artifacts_dir: "x".into() },
            ..ExperimentConfig::gaussian_default()
        };
        assert!(experiment_args(&cfg).is_err());
    }

    #[test]
    fn config_digest_tracks_every_dynamics_knob() {
        let base = ExperimentConfig::gaussian_default();
        let d0 = config_digest(&base);
        assert_eq!(d0, config_digest(&base.clone()), "digest must be deterministic");
        let mut c = base.clone();
        c.beta = 0.1;
        assert_ne!(config_digest(&c), d0, "beta must change the digest");
        let mut c = base.clone();
        c.topology = TopologySpec::Star;
        assert_ne!(config_digest(&c), d0, "topology must change the digest");
        let mut c = base.clone();
        c.diag = crate::algo::wbp::DiagCoef::PaperLiteral;
        assert_ne!(config_digest(&c), d0, "diag variant must change the digest");
        let mut c = base.clone();
        c.faults.drop_prob = 0.05;
        assert_ne!(config_digest(&c), d0, "fault model must change the digest");
        let mut c = base.clone();
        c.kernel = crate::kernel::KernelImpl::Wide;
        assert_ne!(config_digest(&c), d0, "kernel lane width must change the digest");
    }

    #[test]
    fn experiment_args_carry_the_diag_variant() {
        let cfg = ExperimentConfig {
            diag: crate::algo::wbp::DiagCoef::PaperLiteral,
            ..ExperimentConfig::gaussian_default()
        };
        let flags = experiment_args(&cfg).unwrap();
        assert!(flags.iter().any(|f| f == "--paper-literal-diag"));
        let parsed = crate::cli::Args::parse(flags).unwrap();
        let back = ExperimentConfig::from_cli_args(&parsed, false).unwrap();
        assert_eq!(back.diag, crate::algo::wbp::DiagCoef::PaperLiteral);
    }

    #[test]
    fn board_waits_and_fails() {
        let b = Board::new(2);
        b.mark(1, MarkerPhase::SweepDone, 4);
        b.wait_until(Duration::from_millis(50), "sweeps", |s| s.sweeps[1] >= 5).unwrap();
        assert!(b
            .wait_until(Duration::from_millis(20), "more", |s| s.sweeps[1] >= 6)
            .is_err());
        b.fail("boom".into());
        let err = b
            .wait_until(Duration::from_secs(5), "anything", |_| false)
            .unwrap_err();
        assert!(err.contains("boom"));
    }
}
