//! Cross-layer parity: the PJRT-executed AOT artifact (L1 Pallas kernel
//! lowered through the L2 JAX model) must agree with the native Rust
//! oracle to f32 precision. This is the end-to-end proof that the
//! three-layer stack computes the same mathematics.
//!
//! Requires `make artifacts` **and** building with `--features pjrt`
//! (the whole file is compiled out otherwise — the default build ships
//! a stub backend); tests are additionally skipped (with a loud
//! message) when the artifacts directory is absent so `cargo test`
//! stays green in a fresh checkout.
#![cfg(feature = "pjrt")]

use a2dwb::measures::CostRows;
use a2dwb::ot::{dual_oracle, DualOracle};
use a2dwb::rng::Rng64;
use a2dwb::runtime::{read_manifest, PjrtOracle};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if read_manifest(&dir).is_ok() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

fn random_case(seed: u64, m: usize, n: usize, spread: f64) -> (Vec<f64>, CostRows) {
    let mut rng = Rng64::new(seed);
    let eta: Vec<f64> = (0..n).map(|_| spread * rng.normal()).collect();
    let mut cost = CostRows::new(m, n);
    for v in cost.data.iter_mut() {
        *v = rng.uniform(); // normalized costs in [0,1] as in production
    }
    (eta, cost)
}

#[test]
fn pjrt_matches_native_m8_n100() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtOracle::load(&dir, 8, 100).expect("load artifact");
    for seed in 0..5u64 {
        let (eta, cost) = random_case(seed, 8, 100, 0.3);
        for beta in [0.02, 0.1, 1.0] {
            let (g_native, v_native) = dual_oracle(&eta, &cost, beta);
            let mut g_pjrt = vec![0.0; 100];
            let v_pjrt = pjrt.eval(&eta, &cost, beta, &mut g_pjrt);
            let gd = g_native
                .iter()
                .zip(&g_pjrt)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(gd < 5e-6, "seed={seed} beta={beta}: grad diff {gd}");
            assert!(
                (v_native - v_pjrt).abs() < 5e-5 * (1.0 + v_native.abs()),
                "seed={seed} beta={beta}: val {v_native} vs {v_pjrt}"
            );
        }
    }
}

#[test]
fn pjrt_matches_native_all_manifest_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = read_manifest(&dir).unwrap();
    for entry in manifest.iter().filter(|e| e.kind == "oracle") {
        let m: usize = entry.shape.parse().unwrap();
        let n = entry.n;
        let mut pjrt = PjrtOracle::load(&dir, m, n).expect("load");
        let (eta, cost) = random_case(42 + m as u64, m, n, 0.2);
        let (g_native, v_native) = dual_oracle(&eta, &cost, 0.05);
        let mut g_pjrt = vec![0.0; n];
        let v_pjrt = pjrt.eval(&eta, &cost, 0.05, &mut g_pjrt);
        let gd = g_native
            .iter()
            .zip(&g_pjrt)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(gd < 1e-5, "shape ({m},{n}): grad diff {gd}");
        assert!((v_native - v_pjrt).abs() < 1e-4 * (1.0 + v_native.abs()));
        // the PJRT gradient is also a probability distribution
        let s: f64 = g_pjrt.iter().sum();
        assert!((s - 1.0).abs() < 1e-5, "shape ({m},{n}): sum {s}");
    }
}

#[test]
fn pjrt_oracle_reuse_is_stable() {
    // repeated execution of the cached executable gives identical output
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtOracle::load(&dir, 8, 100).expect("load");
    let (eta, cost) = random_case(7, 8, 100, 0.5);
    let mut g1 = vec![0.0; 100];
    let mut g2 = vec![0.0; 100];
    let v1 = pjrt.eval(&eta, &cost, 0.1, &mut g1);
    let v2 = pjrt.eval(&eta, &cost, 0.1, &mut g2);
    assert_eq!(v1, v2);
    assert_eq!(g1, g2);
}

#[test]
fn missing_shape_error_is_actionable() {
    let Some(dir) = artifacts_dir() else { return };
    let err = match PjrtOracle::load(&dir, 7, 13) {
        Ok(_) => panic!("shape (7,13) should not exist"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("compile.aot"), "unhelpful error: {err}");
}

#[test]
fn end_to_end_experiment_on_pjrt_backend() {
    // a tiny full experiment where every activation goes through PJRT
    let Some(dir) = artifacts_dir() else { return };
    use a2dwb::prelude::*;
    let cfg = ExperimentConfig {
        nodes: 6,
        topology: TopologySpec::Complete,
        algorithm: AlgorithmKind::A2dwb,
        measure: MeasureSpec::Gaussian { n: 100 },
        backend: OracleBackendSpec::Pjrt {
            artifacts_dir: dir.to_string_lossy().into_owned(),
        },
        samples_per_activation: 8, // matches oracle_m8_n100 artifact
        eval_samples: 16,
        duration: 3.0,
        metric_interval: 0.5,
        ..ExperimentConfig::gaussian_default()
    };
    let report = run_experiment(&cfg).expect("pjrt experiment");
    assert!(report.final_dual_objective().is_finite());
    assert!(report.activations > 0);
    // and it should agree closely with the native backend run
    let mut cfg_native = cfg.clone();
    cfg_native.backend = OracleBackendSpec::Native;
    let native = run_experiment(&cfg_native).expect("native experiment");
    let d = (report.final_dual_objective() - native.final_dual_objective()).abs();
    assert!(
        d < 1e-3 * (1.0 + native.final_dual_objective().abs()),
        "backend drift {d}"
    );
}
