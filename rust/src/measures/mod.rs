//! Semi-discrete measures — the data substrate of both experiments.
//!
//! Each node holds a private measure `μ_i`; the barycenter lives on a
//! fixed discrete support `{z_1..z_n}`. The only thing the algorithms
//! ever need from a measure is: *draw M samples `Y_r ~ μ_i` and let the
//! kernel read the cost rows `C[r, l] = c(z_l, Y_r)`* (Lemma 1). That
//! contract is the two-step seam
//! [`NodeMeasure::draw_samples_into`] → [`NodeMeasure::cost_rows`]:
//! sampling fills a reusable [`Samples`] buffer (the only per-activation
//! state), and `cost_rows` binds those samples into a zero-copy
//! [`MeasureRows`] source the kernel consumes row by row — no M×n cost
//! buffer is ever materialized on the hot path.
//!
//! Two families, matching the paper's two experiments:
//! * [`gaussian::Gaussian1d`] — continuous `N(θ_i, σ_i²)` on ℝ, support
//!   = n equispaced points on [−5, 5], squared-distance cost (§4.1);
//!   cost generation is fused into the kernel pass
//!   ([`crate::kernel::CostRow::Quad1d`]);
//! * [`digits::DigitMeasure`] — discrete 28×28 image histograms, support
//!   = the same grid, squared Euclidean pixel-distance cost (§4.2);
//!   cost rows are served **by reference** out of the shared precomputed
//!   grid-distance table — zero per-activation cost work at all.
//!   Synthetic glyphs by default; real MNIST IDX files if provided
//!   (see [`idx`] and DESIGN.md §4 for the substitution argument).
//!
//! Determinism contract: [`MeasureSpec::build_network`] and every
//! sampling method are pure functions of the master seed and the RNG
//! stream handed in, which is what lets each backend — and each
//! *shard process* of a multi-process mesh ([`crate::exec::net`]) —
//! rebuild the identical network of measures independently instead of
//! serializing them. This file sits at the bottom of the layer map in
//! `ARCHITECTURE.md`.

pub mod digits;
pub mod gaussian;
pub mod idx;

use crate::kernel::{CostRow, CostRowSource};
use crate::rng::Rng64;

/// Row-major M×n **materialized** cost matrix buffer.
///
/// No longer the hot-path representation (the oracle reads
/// [`MeasureRows`] zero-copy); kept for the PJRT FFI staging path,
/// bench baselines, and tests. Implements
/// [`CostRowSource`](crate::kernel::CostRowSource) so every kernel
/// entry point accepts it unchanged.
#[derive(Clone, Debug)]
pub struct CostRows {
    pub m: usize,
    pub n: usize,
    pub data: Vec<f64>,
}

impl CostRows {
    pub fn new(m: usize, n: usize) -> Self {
        Self { m, n, data: vec![0.0; m * n] }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.n..(r + 1) * self.n]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.n..(r + 1) * self.n]
    }

    /// Materialize every row of `src` into this buffer (shape-checked).
    pub fn fill_from<S: CostRowSource + ?Sized>(&mut self, src: &S) {
        assert_eq!(self.m, src.m(), "row-count mismatch");
        assert_eq!(self.n, src.n(), "support-size mismatch");
        for r in 0..self.m {
            let row = src.cost_row(r);
            row.write_into(self.row_mut(r));
        }
    }
}

/// A compact record of drawn samples, reusable to regenerate cost rows
/// (common-random-number metric evaluation without storing m×E×n costs).
#[derive(Clone, Debug, PartialEq)]
pub enum Samples {
    /// Real-valued sample locations (Gaussian experiment).
    Points1d(Vec<f64>),
    /// Grid pixel indices (digit experiment).
    Pixels(Vec<usize>),
}

impl Samples {
    /// An empty, variant-agnostic buffer for [`NodeMeasure::draw_samples_into`]
    /// to fill (the first draw fixes the variant; later draws reuse the
    /// allocation).
    pub fn empty() -> Self {
        Samples::Points1d(Vec::new())
    }

    pub fn len(&self) -> usize {
        match self {
            Samples::Points1d(v) => v.len(),
            Samples::Pixels(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A batch of drawn samples bound to their measure's cost structure —
/// the zero-copy [`CostRowSource`] the kernel consumes.
///
/// Borrows both the measure's cached geometry (distance table /
/// support) and the caller's [`Samples`] buffer; rebinding after each
/// draw is free.
#[derive(Clone, Copy, Debug)]
pub enum MeasureRows<'a> {
    /// Digit experiment: row `r` is `&table[pixels[r]·n ..][..n]` — a
    /// borrowed view into the shared precomputed grid-distance table.
    Table { table: &'a [f64], n: usize, pixels: &'a [usize] },
    /// Gaussian experiment: `c_l = (support[l] − ys[r])²·inv_scale`,
    /// generated inside the kernel pass.
    Quad1d { support: &'a [f64], ys: &'a [f64], inv_scale: f64 },
}

impl CostRowSource for MeasureRows<'_> {
    fn m(&self) -> usize {
        match self {
            MeasureRows::Table { pixels, .. } => pixels.len(),
            MeasureRows::Quad1d { ys, .. } => ys.len(),
        }
    }

    fn n(&self) -> usize {
        match self {
            MeasureRows::Table { n, .. } => *n,
            MeasureRows::Quad1d { support, .. } => support.len(),
        }
    }

    fn cost_row(&self, r: usize) -> CostRow<'_> {
        match *self {
            MeasureRows::Table { table, n, pixels } => {
                let p = pixels[r];
                CostRow::Borrowed(&table[p * n..(p + 1) * n])
            }
            MeasureRows::Quad1d { support, ys, inv_scale } => {
                CostRow::Quad1d { support, y: ys[r], inv_scale }
            }
        }
    }

    /// Block access without per-row variant dispatch: the match runs
    /// once per block, and each arm serves its whole range from the
    /// shared backing (table slices / the one support slice).
    fn cost_rows_block<'s>(
        &'s self,
        range: std::ops::Range<usize>,
        out: &mut Vec<CostRow<'s>>,
    ) {
        out.clear();
        match *self {
            MeasureRows::Table { table, n, pixels } => {
                out.extend(pixels[range].iter().map(|&p| {
                    CostRow::Borrowed(&table[p * n..(p + 1) * n])
                }));
            }
            MeasureRows::Quad1d { support, ys, inv_scale } => {
                out.extend(
                    ys[range]
                        .iter()
                        .map(|&y| CostRow::Quad1d { support, y, inv_scale }),
                );
            }
        }
    }
}

/// A node's private measure: the sampling oracle of the paper.
pub trait NodeMeasure: Send + Sync {
    /// Support size n (shared across the network).
    fn support_size(&self) -> usize;

    /// Draw `count` samples from μ into `out`, reusing its storage
    /// (steady-state: zero allocation). Implementations must consume
    /// the exact same `Rng64` draw sequence as the retired
    /// `sample_cost_rows` did — one sample per row, in row order — so
    /// sim goldens and common-random-number comparisons are preserved.
    fn draw_samples_into(&self, rng: &mut Rng64, count: usize, out: &mut Samples);

    /// Bind previously drawn samples to a zero-copy cost-row source.
    fn cost_rows<'a>(&'a self, samples: &'a Samples) -> MeasureRows<'a>;

    /// Draw `count` samples into a fresh buffer (metric-evaluator setup
    /// and examples; the hot path uses [`Self::draw_samples_into`]).
    fn draw_samples(&self, rng: &mut Rng64, count: usize) -> Samples {
        let mut out = Samples::empty();
        self.draw_samples_into(rng, count, &mut out);
        out
    }

    /// Sample and **materialize** `out.m` cost rows
    /// `out[r, l] = c(z_l, Y_r)` — the pre-kernel oracle input, kept as
    /// a provided method for bench baselines, FFI staging, and tests.
    /// Identical RNG draws and cost values as the zero-copy path.
    fn sample_cost_rows(&self, rng: &mut Rng64, out: &mut CostRows) {
        let samples = self.draw_samples(rng, out.m);
        let rows = self.cost_rows(&samples);
        out.fill_from(&rows);
    }
}

/// Config-level description of the per-node measure family.
#[derive(Clone, Debug, PartialEq)]
pub enum MeasureSpec {
    /// §4.1: `μ_i = N(θ_i, σ_i²)`, θ_i ~ U[−4,4], σ_i ~ U[0.1,0.6],
    /// support = n points equispaced on [−5, 5].
    Gaussian { n: usize },
    /// §4.2: one image of `digit` per node on a `side × side` grid
    /// (n = side²). Synthetic glyphs, or real MNIST via `idx_path`.
    Digits {
        digit: u8,
        side: usize,
        idx_path: Option<String>,
    },
}

impl MeasureSpec {
    pub fn support_size(&self) -> usize {
        match self {
            MeasureSpec::Gaussian { n } => *n,
            MeasureSpec::Digits { side, .. } => side * side,
        }
    }

    pub fn name(&self) -> String {
        match self {
            MeasureSpec::Gaussian { n } => format!("gaussian-n{n}"),
            MeasureSpec::Digits { digit, side, .. } => {
                format!("digits{digit}-{side}x{side}")
            }
        }
    }

    /// Instantiate the per-node measures for a network of `m` nodes.
    /// Deterministic in `seed`.
    pub fn build_network(
        &self,
        m: usize,
        seed: u64,
    ) -> Vec<Box<dyn NodeMeasure>> {
        self.build_network_with(m, seed, None).0
    }

    /// [`Self::build_network`] with optional cost-table interning: when
    /// an interner is supplied, the measure geometry (grid-distance
    /// table / support lattice) is fetched from — or built into — the
    /// shared registry instead of constructed privately, so N networks
    /// over the same geometry alias one allocation. The per-node
    /// sampling state and the RNG draw sequence are identical either
    /// way; only *where the table lives* changes, which is why interned
    /// and private builds produce bit-identical trajectories.
    pub fn build_network_with(
        &self,
        m: usize,
        seed: u64,
        interner: Option<&TableInterner>,
    ) -> (Vec<Box<dyn NodeMeasure>>, NetworkTables) {
        let mut rng = Rng64::new(seed ^ 0x4D45_4153);
        match self {
            MeasureSpec::Gaussian { n } => {
                let (support, hit) = match interner {
                    Some(i) => i.support1d(*n),
                    None => (
                        std::sync::Arc::new(gaussian::linspace(-5.0, 5.0, *n)),
                        false,
                    ),
                };
                let measures = (0..m)
                    .map(|_| {
                        // θ_i ~ U[-4, 4], σ_i ~ U[0.1, 0.6]  (paper §4.1)
                        let theta = rng.uniform_in(-4.0, 4.0);
                        let sigma = rng.uniform_in(0.1, 0.6);
                        Box::new(gaussian::Gaussian1d::new(theta, sigma, support.clone()))
                            as Box<dyn NodeMeasure>
                    })
                    .collect();
                let tables = NetworkTables {
                    grid: None,
                    support: Some(support),
                    hits: u64::from(hit),
                    misses: u64::from(!hit),
                };
                (measures, tables)
            }
            MeasureSpec::Digits { digit, side, idx_path } => {
                let images = match idx_path {
                    Some(p) => idx::load_digit_images(p, *digit, m, *side)
                        .unwrap_or_else(|e| {
                            eprintln!(
                                "warn: IDX load failed ({e}); using synthetic glyphs"
                            );
                            digits::synthetic_images(*digit, m, *side, &mut rng)
                        }),
                    None => digits::synthetic_images(*digit, m, *side, &mut rng),
                };
                let (geom, hit) = match interner {
                    Some(i) => i.grid(*side),
                    None => (
                        std::sync::Arc::new(digits::GridGeometry::new(*side)),
                        false,
                    ),
                };
                let measures = images
                    .into_iter()
                    .map(|img| {
                        Box::new(digits::DigitMeasure::new(img, geom.clone()))
                            as Box<dyn NodeMeasure>
                    })
                    .collect();
                let tables = NetworkTables {
                    grid: Some(geom),
                    support: None,
                    hits: u64::from(hit),
                    misses: u64::from(!hit),
                };
                (measures, tables)
            }
        }
    }
}

/// The geometry tables a built network aliases, plus whether this
/// build hit or missed the interner — handed back to the caller so a
/// batching layer can recover row identity by pointer and telemetry
/// can count dedup ([`crate::obs::Counter::TableCacheHits`]).
#[derive(Clone, Debug, Default)]
pub struct NetworkTables {
    /// Shared grid geometry (digits experiment), if any.
    pub grid: Option<std::sync::Arc<digits::GridGeometry>>,
    /// Shared 1-D support lattice (Gaussian experiment), if any.
    pub support: Option<std::sync::Arc<Vec<f64>>>,
    /// Interner hits this build observed (0 or 1 per build).
    pub hits: u64,
    /// Interner misses this build observed (0 or 1 per build).
    pub misses: u64,
}

/// Process-wide cost-table registry: interns the O(n²) grid-distance
/// table and the O(n) support lattice by their *complete* geometry
/// fingerprints, so N concurrent sessions over the same support share
/// one allocation instead of paying it per tenant.
///
/// The fingerprints really are complete: [`digits::GridGeometry::new`]
/// is a pure function of `side` (coords, normalization, and distance
/// table all derive from it), and the Gaussian support is always
/// `linspace(-5, 5, n)` — so the map keys `side` / `n` pin every byte
/// of the interned value. Tables are built *inside* the lock: when K
/// sessions race on a cold key, exactly one pays the miss and the
/// other K−1 count hits, which keeps the telemetry assertions in tests
/// and CI deterministic (the build is milliseconds, once per geometry,
/// off the hot path).
#[derive(Debug, Default)]
pub struct TableInterner {
    grids: std::sync::Mutex<
        std::collections::HashMap<usize, std::sync::Arc<digits::GridGeometry>>,
    >,
    supports: std::sync::Mutex<
        std::collections::HashMap<usize, std::sync::Arc<Vec<f64>>>,
    >,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl TableInterner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-build the shared grid geometry for a `side × side`
    /// digit grid. Returns `(table, was_hit)`.
    pub fn grid(&self, side: usize) -> (std::sync::Arc<digits::GridGeometry>, bool) {
        use std::sync::atomic::Ordering;
        let mut map = self.grids.lock().unwrap();
        match map.get(&side) {
            Some(g) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                (g.clone(), true)
            }
            None => {
                let g = std::sync::Arc::new(digits::GridGeometry::new(side));
                map.insert(side, g.clone());
                self.misses.fetch_add(1, Ordering::Relaxed);
                (g, false)
            }
        }
    }

    /// Fetch-or-build the shared Gaussian support `linspace(-5, 5, n)`.
    /// Returns `(support, was_hit)`.
    pub fn support1d(&self, n: usize) -> (std::sync::Arc<Vec<f64>>, bool) {
        use std::sync::atomic::Ordering;
        let mut map = self.supports.lock().unwrap();
        match map.get(&n) {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                (s.clone(), true)
            }
            None => {
                let s = std::sync::Arc::new(gaussian::linspace(-5.0, 5.0, n));
                map.insert(n, s.clone());
                self.misses.fetch_add(1, Ordering::Relaxed);
                (s, false)
            }
        }
    }

    /// Lifetime hit count across all lookups.
    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Lifetime miss count across all lookups.
    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Bytes resident in interned tables right now — the denominator of
    /// the dedup ratio `BENCH_serve.json` reports. Counts the f64
    /// payloads (dist + coords per grid, the lattice per support);
    /// O(1) in tenant count by construction.
    pub fn resident_bytes(&self) -> usize {
        let f64s = std::mem::size_of::<f64>();
        let grids: usize = self
            .grids
            .lock()
            .unwrap()
            .values()
            .map(|g| (g.dist.len() + 2 * g.coords.len()) * f64s)
            .sum();
        let supports: usize = self
            .supports
            .lock()
            .unwrap()
            .values()
            .map(|s| s.len() * f64s)
            .sum();
        grids + supports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_rows_layout() {
        let mut c = CostRows::new(2, 3);
        c.row_mut(1)[2] = 5.0;
        assert_eq!(c.data[5], 5.0);
        assert_eq!(c.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn gaussian_network_deterministic() {
        let spec = MeasureSpec::Gaussian { n: 10 };
        let a = spec.build_network(4, 1);
        let b = spec.build_network(4, 1);
        let mut r1 = Rng64::new(9);
        let mut r2 = Rng64::new(9);
        let mut ca = CostRows::new(3, 10);
        let mut cb = CostRows::new(3, 10);
        a[2].sample_cost_rows(&mut r1, &mut ca);
        b[2].sample_cost_rows(&mut r2, &mut cb);
        assert_eq!(ca.data, cb.data);
    }

    #[test]
    fn digits_network_builds() {
        let spec = MeasureSpec::Digits { digit: 3, side: 14, idx_path: None };
        let ms = spec.build_network(3, 2);
        assert_eq!(ms.len(), 3);
        assert_eq!(ms[0].support_size(), 196);
        let mut rng = Rng64::new(0);
        let mut c = CostRows::new(4, 196);
        ms[0].sample_cost_rows(&mut rng, &mut c);
        // costs are normalized squared grid distances in [0, 2]
        assert!(c.data.iter().all(|&x| (0.0..=2.0 + 1e-12).contains(&x)));
    }
}
