//! The observability contract of `a2dwb::obs` end to end:
//!
//! * on the simulator (`workers = 1` equivalent: one event loop), the
//!   telemetry snapshot is a **deterministic function of the config** —
//!   two identical runs produce identical tables, and the counters
//!   reconcile exactly with the report's totals;
//! * DCWB's gate-wait histogram carries the paper's waiting overhead
//!   (virtual seconds blocked on the round barrier) while the
//!   barrier-free A²DWB records none — the `speedup` contrast;
//! * arming the trace ring never perturbs the trajectory: telemetry
//!   observes RNG-free, so the metric series is bit-identical with
//!   tracing on or off;
//! * the threaded executor fills the same tables (per-node activation
//!   registry, per-worker claim table) with the same totals.

use a2dwb::obs::{Counter, HistKind};
use a2dwb::prelude::*;

fn tiny(alg: AlgorithmKind) -> ExperimentConfig {
    ExperimentConfig {
        nodes: 3,
        topology: TopologySpec::Cycle,
        algorithm: alg,
        measure: MeasureSpec::Gaussian { n: 12 },
        samples_per_activation: 6,
        eval_samples: 8,
        duration: 2.0,
        metric_interval: 0.5,
        ..ExperimentConfig::gaussian_default()
    }
}

fn series_bits(s: &Series) -> Vec<(u64, u64)> {
    s.points.iter().map(|&(t, v)| (t.to_bits(), v.to_bits())).collect()
}

#[test]
fn sim_telemetry_is_deterministic_and_reconciles_with_the_report() {
    let cfg = tiny(AlgorithmKind::A2dwb);
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.telemetry, b.telemetry, "sim telemetry must be deterministic");

    let t = &a.telemetry;
    // every activation lands in the per-node registry, once
    assert_eq!(t.counter(Counter::Activations), a.activations);
    assert_eq!(t.node_activations.len(), cfg.nodes);
    assert_eq!(t.node_activations.iter().sum::<u64>(), a.activations);
    // edge-granularity sends reconcile with the report total
    assert_eq!(t.counter(Counter::Messages), a.messages);
    // every send is classified exactly once: delivered frames split
    // publish/stale-drop, the rest were lost on the wire
    let delivered =
        t.counter(Counter::MailboxPublishes) + t.counter(Counter::MailboxStaleDrops);
    assert!(delivered <= a.messages);
    assert!(t.counter(Counter::MailboxPublishes) > 0);
    // one staleness sample per neighbor slot per activation (the
    // 3-cycle is 2-regular), same definition the threaded MailboxGrid
    // records — the histograms are cross-backend comparable
    let lag = t.hist(HistKind::StampLag).expect("stamp-lag histogram");
    assert_eq!(lag.count, a.activations * 2);
    // the dual oracle is exercised once per activation plus the initial
    // exchange and evaluator passes; it must at least cover activations
    assert!(t.counter(Counter::OraclePasses) >= a.activations);
    // barrier-free: no gate waits on the async algorithm
    assert_eq!(t.counter(Counter::GateWaits), 0);
    assert_eq!(t.gate_wait_secs(), 0.0);
    // single-process run: the wire tables stay empty
    assert_eq!(t.wire_frames_sent(), 0);
    assert_eq!(a.wire_messages(), 0);
}

#[test]
fn dcwb_gate_wait_carries_the_waiting_overhead_a2dwb_removes() {
    let sync = run_experiment(&tiny(AlgorithmKind::Dcwb)).unwrap();
    let async_ = run_experiment(&tiny(AlgorithmKind::A2dwb)).unwrap();
    let gate = sync.telemetry.hist(HistKind::GateWaitNs).expect("gate-wait histogram");
    // one barrier per round, each waiting on the slowest edge
    assert_eq!(sync.telemetry.counter(Counter::GateWaits), sync.rounds);
    assert_eq!(gate.count, sync.rounds);
    assert!(
        sync.telemetry.gate_wait_secs() > 0.0,
        "the synchronous baseline must pay for its barrier"
    );
    assert_eq!(async_.telemetry.gate_wait_secs(), 0.0);
}

#[test]
fn tracing_never_perturbs_the_trajectory() {
    let cfg = tiny(AlgorithmKind::A2dwb);
    let plain = run_experiment(&cfg).unwrap();

    let session = Session::from_config(cfg).unwrap();
    let obs = session.telemetry();
    obs.set_trace_capacity(4096);
    let traced = session.run().unwrap();

    assert_eq!(
        series_bits(&traced.dual_objective),
        series_bits(&plain.dual_objective),
        "arming the trace ring must not move a single bit"
    );
    assert_eq!(traced.barycenter, plain.barycenter);

    let (events, dropped) = obs.drain_trace();
    assert_eq!(dropped, 0);
    assert_eq!(
        events.iter().filter(|e| e.kind == "activate").count() as u64,
        traced.activations,
        "one activate trace event per activation"
    );
    // virtual timestamps come off the event queue, so they are monotone
    for w in events.windows(2) {
        assert!(w[1].t_ns >= w[0].t_ns, "non-monotone trace: {:?} {:?}", w[0], w[1]);
    }
}

#[test]
fn threaded_executor_fills_the_same_tables() {
    let cfg = ExperimentConfig {
        executor: ExecutorSpec::Threads { workers: 2 },
        compute_time: 0.0,
        ..tiny(AlgorithmKind::A2dwb)
    };
    let report = run_experiment(&cfg).unwrap();
    let t = &report.telemetry;
    assert_eq!(t.counter(Counter::Activations), report.activations);
    assert_eq!(t.node_activations.iter().sum::<u64>(), report.activations);
    assert_eq!(t.counter(Counter::Messages), report.messages);
    // the worker-claim table accounts for every activation across the pool
    assert_eq!(t.worker_claims.len(), 2);
    assert_eq!(t.worker_claims.iter().sum::<u64>(), report.activations);
    // pull-based mailbox reads record the same staleness definition
    let lag = t.hist(HistKind::StampLag).expect("stamp-lag histogram");
    assert!(lag.count > 0);
}
