//! Event-driven execution of A²DWB / A²DWBN (Algorithm 3).
//!
//! Event kinds:
//! * `Activate(i)` — node i wakes (shared `perm(m)` schedule, §3.3):
//!   evaluates its local point, calls the dual oracle on a fresh sample
//!   batch, broadcasts the gradient to neighbors (delayed messages) and
//!   applies the Laplacian combine with whatever stale neighbor
//!   gradients its mailbox holds — no barrier, the paper's key point.
//! * `Deliver{dst, slot, k, grad}` — a gradient message lands; the
//!   mailbox keeps the freshest per neighbor (out-of-order safe).
//! * `Metric` — sample the metric series on the fixed grid.
//!
//! The initial gradient exchange (Algorithm 3 line 1) is modeled as a
//! round of messages sent at t = 0 with normal link delays.

use std::rc::Rc;

use super::{evaluator::MetricsEvaluator, ExperimentConfig, ExperimentReport};
use crate::algo::wbp::WbpNode;
use crate::algo::ThetaSeq;
use crate::graph::Graph;
use crate::measures::CostRows;
use crate::metrics::Series;
use crate::sim::{ActivationSchedule, EventQueue, LinkDelayModel};

enum Event {
    Activate(usize),
    /// Gradient message in flight. The payload is `Rc`-shared across the
    /// sender's whole broadcast: one allocation per activation instead of
    /// deg(i) clones (§Perf item 3 — the top allocator on dense graphs).
    Deliver { dst: usize, slot: usize, computed_at: u64, grad: Rc<Vec<f64>> },
    Metric,
}

pub(super) fn run(
    cfg: &ExperimentConfig,
    graph: &Graph,
    compensated: bool,
) -> Result<ExperimentReport, String> {
    let m = cfg.nodes;
    let n = cfg.support_size();
    let measures = cfg.measure.build_network(m, cfg.seed);
    let mut oracle = cfg
        .backend
        .build(cfg.samples_per_activation, n)
        .map_err(|e| e.to_string())?;
    let lambda_max = graph.lambda_max();
    let smoothness = lambda_max / cfg.beta;
    let gamma = cfg.gamma_scale / smoothness;

    let mut theta = ThetaSeq::new(m);
    let mut nodes: Vec<WbpNode> =
        (0..m).map(|i| WbpNode::new(n, graph.degree(i))).collect();
    // slot index of node `src` in `dst`'s sorted neighbor list
    let slot_of = |dst: usize, src: usize| -> usize {
        graph.neighbors(dst).binary_search(&src).expect("not a neighbor")
    };

    let mut delays = LinkDelayModel::paper_default(m, cfg.seed);
    // fault model: straggler delay multipliers + message-loss stream
    let node_factors = cfg.faults.node_factors(m, cfg.seed);
    let drop_prob = cfg.faults.drop_prob;
    let mut drop_rng = crate::rng::Rng64::new(cfg.seed ^ 0x4452_4F50);
    let mut schedule = ActivationSchedule::new(m, cfg.activation_interval, cfg.seed);
    let mut queue: EventQueue<Event> = EventQueue::new();
    let mut evaluator =
        MetricsEvaluator::new(graph, &measures, cfg.beta, cfg.eval_samples, cfg.seed);

    // per-node sampling streams (split off the master seed)
    let mut root = crate::rng::Rng64::new(cfg.seed ^ 0x5254_4E44);
    let mut node_rngs: Vec<crate::rng::Rng64> =
        (0..m).map(|i| root.split(i as u64)).collect();

    let mut dual_series = Series::new("dual_objective");
    let mut consensus_series = Series::new("consensus");
    let mut spread_series = Series::new("primal_spread");

    let mut cost = CostRows::new(cfg.samples_per_activation, n);
    let mut point = vec![0.0; n];
    let mut etas = vec![0.0; m * n];
    let mut messages: u64 = 0;
    let mut activations: u64 = 0;
    let mut k_global: usize = 0; // shared activation counter (common seed)

    // ---- Algorithm 3 line 1: initial gradient computation + exchange
    for i in 0..m {
        nodes[i].eval_point(&mut theta, 0, true, &mut point);
        measures[i].sample_cost_rows(&mut node_rngs[i], &mut cost);
        let mut g = vec![0.0; n];
        oracle.eval(&point, &cost, cfg.beta, &mut g);
        nodes[i].own_grad.copy_from_slice(&g);
        let g = Rc::new(g);
        for &j in graph.neighbors(i) {
            messages += 1;
            if drop_prob > 0.0 && drop_rng.uniform() < drop_prob {
                continue; // lost on the wire; mailbox keeps the default
            }
            let delay = delays.draw(i, j) * node_factors[i].max(node_factors[j]);
            queue.schedule(
                delay + cfg.compute_time,
                Event::Deliver {
                    dst: j,
                    slot: slot_of(j, i),
                    computed_at: 0,
                    grad: g.clone(),
                },
            );
        }
    }

    // first activation + metric events
    {
        let (t, node) = schedule.next_activation();
        queue.schedule(t.max(f64::EPSILON), Event::Activate(node));
    }
    queue.schedule(0.0, Event::Metric);

    // ---- main event loop
    while let Some(ev) = queue.pop_until(cfg.duration) {
        match ev.payload {
            Event::Activate(i) => {
                let k = k_global;
                // line 5: evaluation point (compensated vs naive)
                nodes[i].eval_point(&mut theta, k, compensated, &mut point);
                // line 6: sample M_k, oracle gradient
                measures[i].sample_cost_rows(&mut node_rngs[i], &mut cost);
                oracle.eval(&point, &cost, cfg.beta, &mut nodes[i].own_grad);
                // broadcast g_i to neighbors with per-link delays; one
                // shared Rc payload for the whole broadcast
                let g = Rc::new(nodes[i].own_grad.clone());
                for &j in graph.neighbors(i) {
                    messages += 1;
                    if drop_prob > 0.0 && drop_rng.uniform() < drop_prob {
                        continue; // lost message: neighbor keeps stale grad
                    }
                    let delay =
                        delays.draw(i, j) * node_factors[i].max(node_factors[j]);
                    queue.schedule_in(
                        delay + cfg.compute_time,
                        Event::Deliver {
                            dst: j,
                            slot: slot_of(j, i),
                            computed_at: k as u64 + 1,
                            grad: g.clone(),
                        },
                    );
                }
                // lines 7–8: combine with stale mailbox + update (u, v)
                nodes[i].apply_update(
                    &mut theta,
                    k,
                    m,
                    gamma,
                    graph.degree(i),
                    cfg.diag,
                );
                k_global += 1;
                activations += 1;
                // schedule the next activation from the shared sequence
                let (t, node) = schedule.next_activation();
                if t <= cfg.duration {
                    queue.schedule(t.max(queue.now()), Event::Activate(node));
                }
            }
            Event::Deliver { dst, slot, computed_at, grad } => {
                nodes[dst].deliver(slot, computed_at, &grad);
            }
            Event::Metric => {
                let t = queue.now();
                for (i, node) in nodes.iter().enumerate() {
                    node.eta(&mut theta, k_global.max(1), &mut point);
                    etas[i * n..(i + 1) * n].copy_from_slice(&point);
                }
                let (dual, consensus, spread) = evaluator.evaluate(&etas, &measures);
                dual_series.push(t, dual);
                consensus_series.push(t, consensus);
                spread_series.push(t, spread);
                if t + cfg.metric_interval <= cfg.duration {
                    queue.schedule_in(cfg.metric_interval, Event::Metric);
                }
            }
        }
    }

    // final metric point at the horizon
    for (i, node) in nodes.iter().enumerate() {
        node.eta(&mut theta, k_global.max(1), &mut point);
        etas[i * n..(i + 1) * n].copy_from_slice(&point);
    }
    let (dual, consensus, spread) = evaluator.evaluate(&etas, &measures);
    dual_series.push(cfg.duration, dual);
    consensus_series.push(cfg.duration, consensus);
    spread_series.push(cfg.duration, spread);

    Ok(ExperimentReport {
        tag: cfg.tag(),
        algorithm: cfg.algorithm,
        dual_objective: dual_series,
        consensus: consensus_series,
        primal_spread: spread_series,
        activations,
        rounds: 0,
        messages,
        events: queue.processed(),
        lambda_max,
        wall_seconds: 0.0,
        barycenter: evaluator.barycenter(),
    })
}
