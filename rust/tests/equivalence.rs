//! Theorem 3 — ASBCDS and PASBCDS produce identical trajectories when
//! fed the same staleness schedule j_p(k+1) and the same noise ξ_{k+1}.
//!
//! We check the mapping λ/ζ/η ↔ u/v numerically on random quadratics,
//! random delay schedules, and random block sequences — far stronger
//! than a single fixed case.

use a2dwb::algo::asbcds::Asbcds;
use a2dwb::algo::pasbcds::Pasbcds;
use a2dwb::algo::schedule::{FreshSchedule, UniformDelaySchedule};
use a2dwb::algo::BlockFn;
use a2dwb::problems::QuadraticBlockFn;
use a2dwb::proptest_util::{gen_usize, PropCheck};
use a2dwb::rng::Rng64;

/// Max |a−b| across a vector pair.
fn max_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn run_pair(
    m: usize,
    n: usize,
    sigma: f64,
    tau: usize,
    iters: usize,
    seed: u64,
) -> f64 {
    let x0: Vec<f64> = {
        let mut rng = Rng64::new(seed ^ 1);
        (0..m * n).map(|_| rng.normal()).collect()
    };
    let blocks: Vec<usize> = {
        let mut rng = Rng64::new(seed ^ 2);
        (0..iters).map(|_| rng.below(m as u64) as usize).collect()
    };

    // Two *independent* problem instances with the same seed: identical
    // A, b, and iteration-keyed noise — the Theorem 3 precondition.
    let mut p1 = QuadraticBlockFn::random(m, n, sigma, seed);
    let mut p2 = QuadraticBlockFn::random(m, n, sigma, seed);
    let gamma = 0.05 / p1.smoothness();

    let mut worst: f64 = 0.0;
    if tau <= 1 {
        let mut a = Asbcds::new(&mut p1, FreshSchedule, gamma, &x0);
        let mut b = Pasbcds::new(&mut p2, FreshSchedule, gamma, &x0);
        for &blk in &blocks {
            a.step(blk);
            b.step(blk);
            worst = worst.max(max_diff(&a.eta, &b.eta()));
            worst = worst.max(max_diff(&a.zeta, &b.u));
        }
    } else {
        let s1 = UniformDelaySchedule::new(tau, seed ^ 3);
        let s2 = UniformDelaySchedule::new(tau, seed ^ 3);
        let mut a = Asbcds::new(&mut p1, s1, gamma, &x0);
        let mut b = Pasbcds::new(&mut p2, s2, gamma, &x0);
        for &blk in &blocks {
            a.step(blk);
            b.step(blk);
            worst = worst.max(max_diff(&a.eta, &b.eta()));
            worst = worst.max(max_diff(&a.zeta, &b.u));
        }
    }
    worst
}

#[test]
fn equivalence_fresh_schedule() {
    let d = run_pair(4, 3, 0.0, 1, 120, 11);
    assert!(d < 1e-9, "fresh-schedule divergence {d}");
}

#[test]
fn equivalence_with_staleness() {
    let d = run_pair(5, 2, 0.0, 4, 200, 13);
    assert!(d < 1e-8, "stale-schedule divergence {d}");
}

#[test]
fn equivalence_with_noise() {
    // stochastic gradients: the keyed noise must match between the two
    let d = run_pair(3, 4, 0.3, 3, 150, 17);
    assert!(d < 1e-8, "noisy divergence {d}");
}

#[test]
fn equivalence_property_sweep() {
    PropCheck::new("theorem-3 equivalence", 0xA2D3, 12).run(|rng| {
        let m = gen_usize(rng, 2, 6);
        let n = gen_usize(rng, 1, 4);
        let tau = gen_usize(rng, 1, 5);
        let iters = gen_usize(rng, 30, 120);
        let sigma = if rng.uniform() < 0.5 { 0.0 } else { 0.2 };
        let seed = rng.next_u64();
        let d = run_pair(m, n, sigma, tau, iters, seed);
        if d > 1e-7 {
            return Err(format!(
                "divergence {d} at m={m} n={n} tau={tau} iters={iters}"
            ));
        }
        Ok(())
    });
}

#[test]
fn both_reach_same_final_value() {
    let mut p1 = QuadraticBlockFn::random(4, 3, 0.0, 23);
    let mut p2 = QuadraticBlockFn::random(4, 3, 0.0, 23);
    let x0 = vec![1.0; 12];
    let gamma = 0.2 / p1.smoothness();
    let blocks: Vec<usize> = {
        let mut rng = Rng64::new(99);
        (0..600).map(|_| rng.below(4) as usize).collect()
    };
    let mut a = Asbcds::new(&mut p1, FreshSchedule, gamma, &x0);
    let mut b = Pasbcds::new(&mut p2, FreshSchedule, gamma, &x0);
    for &blk in &blocks {
        a.step(blk);
        b.step(blk);
    }
    let va = a.value();
    let vb = b.value_at_eta();
    assert!((va - vb).abs() < 1e-9, "{va} vs {vb}");
}
