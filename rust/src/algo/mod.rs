//! The paper's algorithms.
//!
//! * [`theta`] — the shared acceleration sequence θ_k (Lemma 2).
//! * [`asbcds`] / [`pasbcds`] — the generic inducing methods
//!   (Algorithms 1 and 2) over an abstract smooth stochastic objective
//!   ([`BlockFn`]); Theorem 3 equivalence is tested on these.
//! * [`wbp`] — the node-local state machine shared by A²DWB, A²DWBN and
//!   DCWB (Algorithm 3 instantiated on the WBP dual); the event-driven
//!   network execution lives in [`crate::coordinator`].
//! * [`schedule`] — staleness schedules `j_p(k+1)` for the generic
//!   methods.

pub mod asbcds;
pub mod pasbcds;
pub mod schedule;
pub mod theta;
pub mod wbp;

pub use schedule::{DelaySchedule, FreshSchedule, UniformDelaySchedule};
pub use theta::ThetaSeq;

/// Which algorithm a coordinator run executes (paper §4 compares three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    /// Algorithm 3: asynchronous, momentum-compensated (the paper's).
    A2dwb,
    /// Naive asynchronous: stale gradients without compensation.
    A2dwbn,
    /// Synchronous baseline (Dvurechenskii et al. 2018 Alg. 3): global
    /// barrier each round, waits for the slowest edge.
    Dcwb,
}

impl AlgorithmKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::A2dwb => "a2dwb",
            AlgorithmKind::A2dwbn => "a2dwbn",
            AlgorithmKind::Dcwb => "dcwb",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "a2dwb" | "async" => Ok(AlgorithmKind::A2dwb),
            "a2dwbn" | "naive" => Ok(AlgorithmKind::A2dwbn),
            "dcwb" | "sync" => Ok(AlgorithmKind::Dcwb),
            other => Err(format!("unknown algorithm '{other}'")),
        }
    }

    pub fn all() -> [AlgorithmKind; 3] {
        [AlgorithmKind::A2dwb, AlgorithmKind::A2dwbn, AlgorithmKind::Dcwb]
    }

    /// Stable wire code (the `algo` byte of the mesh handshake and the
    /// v6 session-event frames). Inverse of [`AlgorithmKind::from_code`].
    pub fn code(&self) -> u8 {
        match self {
            AlgorithmKind::A2dwb => 0,
            AlgorithmKind::A2dwbn => 1,
            AlgorithmKind::Dcwb => 2,
        }
    }

    /// Decode a wire code produced by [`AlgorithmKind::code`].
    pub fn from_code(code: u8) -> Result<Self, String> {
        match code {
            0 => Ok(AlgorithmKind::A2dwb),
            1 => Ok(AlgorithmKind::A2dwbn),
            2 => Ok(AlgorithmKind::Dcwb),
            other => Err(format!("unknown algorithm code {other}")),
        }
    }
}

/// Abstract L-smooth stochastic objective over `m` blocks of dimension
/// `n` — the φ(η) of the paper's §2.2 general primal-dual formulation.
///
/// `partial_grad` must be a *deterministic function of (x, block, k)*:
/// the iteration index keys the noise stream. This is what makes the
/// ASBCDS ↔ PASBCDS equivalence (Theorem 3) testable — both algorithms
/// see identical ξ_{k+1} draws.
pub trait BlockFn {
    /// Number of blocks m.
    fn num_blocks(&self) -> usize;
    /// Block dimension n.
    fn block_dim(&self) -> usize;
    /// Deterministic objective value φ(x) (expectation, for metrics).
    fn value(&self, x: &[f64]) -> f64;
    /// Stochastic partial gradient ∇φ(x, ξ_k)^[block] into `out` (len n).
    fn partial_grad(&mut self, x: &[f64], block: usize, k: usize, out: &mut [f64]);
    /// Exact full gradient (tests / baselines).
    fn full_grad(&self, x: &[f64], out: &mut [f64]);
    /// Smoothness constant L (sets the admissible step size).
    fn smoothness(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in AlgorithmKind::all() {
            assert_eq!(AlgorithmKind::parse(k.name()).unwrap(), k);
        }
        assert!(AlgorithmKind::parse("bogus").is_err());
    }
}
