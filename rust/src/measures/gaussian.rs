//! §4.1 substrate: univariate Gaussian node measures.
//!
//! `μ_i = N(θ_i, σ_i²)` sampled exactly (Box–Muller); support is `n`
//! equispaced points on `[−5, 5]`; transport cost is squared distance,
//! normalized by the squared support radius so that costs live in O(1)
//! regardless of n — this keeps one `β` meaningful across experiments.
//!
//! Cost rows are never materialized: [`NodeMeasure::cost_rows`] binds
//! the drawn sample locations to a [`MeasureRows::Quad1d`] source and
//! the kernel generates `(z_l − Y_r)²·inv_scale` inside its softmax
//! pass (bit-identical to the retired materialize-then-softmax path).

use std::sync::Arc;

use super::{MeasureRows, NodeMeasure, Samples};
use crate::rng::Rng64;

/// `n` equispaced points on [lo, hi] (inclusive endpoints).
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2, "linspace needs n >= 2");
    let step = (hi - lo) / (n - 1) as f64;
    (0..n).map(|i| lo + step * i as f64).collect()
}

/// One node's continuous measure for the Gaussian experiment.
#[derive(Clone, Debug)]
pub struct Gaussian1d {
    pub theta: f64,
    pub sigma: f64,
    support: Arc<Vec<f64>>,
    /// 1 / (radius²) cost normalizer, radius = max |z|.
    inv_scale: f64,
}

impl Gaussian1d {
    pub fn new(theta: f64, sigma: f64, support: Arc<Vec<f64>>) -> Self {
        assert!(sigma > 0.0);
        let radius = support
            .iter()
            .map(|z| z.abs())
            .fold(0.0f64, f64::max)
            .max(1e-12);
        Self { theta, sigma, support, inv_scale: 1.0 / (radius * radius) }
    }

    pub fn support(&self) -> &[f64] {
        &self.support
    }
}

impl NodeMeasure for Gaussian1d {
    fn support_size(&self) -> usize {
        self.support.len()
    }

    fn draw_samples_into(&self, rng: &mut Rng64, count: usize, out: &mut Samples) {
        // Same draw sequence as the retired sample_cost_rows: one
        // Box–Muller draw per row, in row order.
        if !matches!(out, Samples::Points1d(_)) {
            *out = Samples::Points1d(Vec::new());
        }
        let Samples::Points1d(ys) = out else { unreachable!() };
        ys.clear();
        ys.reserve(count);
        for _ in 0..count {
            ys.push(rng.normal_with(self.theta, self.sigma));
        }
    }

    fn cost_rows<'a>(&'a self, samples: &'a Samples) -> MeasureRows<'a> {
        let Samples::Points1d(ys) = samples else {
            panic!("Gaussian1d expects Points1d samples");
        };
        MeasureRows::Quad1d {
            support: &self.support[..],
            ys,
            inv_scale: self.inv_scale,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::CostRows;

    #[test]
    fn linspace_endpoints_and_spacing() {
        let xs = linspace(-5.0, 5.0, 101);
        assert_eq!(xs.len(), 101);
        assert!((xs[0] + 5.0).abs() < 1e-12);
        assert!((xs[100] - 5.0).abs() < 1e-12);
        assert!((xs[1] - xs[0] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn cost_rows_are_parabolas_in_support() {
        let sup = Arc::new(linspace(-5.0, 5.0, 11));
        let g = Gaussian1d::new(0.0, 0.1, sup.clone());
        let mut rng = Rng64::new(3);
        let mut cr = CostRows::new(1, 11);
        g.sample_cost_rows(&mut rng, &mut cr);
        // the sampled y is near 0 (σ=0.1) ⇒ min cost near the middle
        let row = cr.row(0);
        let argmin = row
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!((4..=6).contains(&argmin), "argmin {argmin}");
        // normalized: cost at |z|=5 when y≈0 is ≈ 25/25 = 1
        assert!(row[0] <= 1.5 && row[10] <= 1.5);
    }

    #[test]
    fn sample_mean_tracks_theta() {
        let sup = Arc::new(linspace(-5.0, 5.0, 3));
        let g = Gaussian1d::new(2.0, 0.5, sup);
        let mut rng = Rng64::new(5);
        let mut cr = CostRows::new(1, 3);
        // recover y from the cost row: y = z0 ± sqrt(c*scale)... easier:
        // estimate E[y] by sampling many rows and inverting the parabola
        // vertex via finite differences on the 3 support points.
        let z = [-5.0, 0.0, 5.0];
        let mut mean = 0.0;
        let trials = 4000;
        for _ in 0..trials {
            g.sample_cost_rows(&mut rng, &mut cr);
            let c: Vec<f64> = cr.row(0).iter().map(|v| v * 25.0).collect();
            // c_l = (z_l - y)^2 ⇒ y = (c_0 - c_2) / (2(z_2 - z_0)) ... solve:
            let y = (c[0] - c[2]) / (2.0 * (z[2] - z[0]));
            mean += y;
        }
        mean /= trials as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }
}
