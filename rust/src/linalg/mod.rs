//! Dense/sparse linear algebra substrate (replaces nalgebra/sprs).
//!
//! Needs of this paper: graph Laplacians (sparse apply on the hot path),
//! their square roots `√W` (dense, small-m, for Theorem-1/3 validation),
//! eigenvalues (`λ_max(W)` sets the dual smoothness constant `L` and
//! therefore the step size), and assorted vector kernels used by the
//! algorithms.

mod eigen;
mod sparse;

pub use eigen::{jacobi_eigen, sqrtm_psd, EigenDecomposition};
pub use sparse::CsrMatrix;

/// Dense row-major matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// C = A B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut c = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let crow = &mut c.data[i * other.cols..(i + 1) * other.cols];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Max |a_ij − b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Largest eigenvalue of a symmetric PSD matrix by power iteration.
    pub fn lambda_max_power(&self, iters: usize) -> f64 {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        if n == 0 {
            return 0.0;
        }
        // deterministic start vector with nonzero overlap w.h.p.
        let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
        let mut lambda = 0.0;
        for _ in 0..iters {
            let w = self.matvec(&v);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0;
            }
            lambda = dot(&v, &w) / dot(&v, &v);
            v = w.iter().map(|x| x / norm).collect();
        }
        lambda
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

// ------------------------------------------------------------ vector ops

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

pub fn dist2_sq(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = Mat::identity(2);
        assert_eq!(a.matmul(&b), a);
        let c = a.matmul(&a);
        assert_eq!(c[(0, 0)], 7.0);
        assert_eq!(c[(1, 1)], 22.0);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn power_iteration_diag() {
        let mut d = Mat::zeros(4, 4);
        for (i, v) in [0.5, 3.0, 2.0, 0.1].iter().enumerate() {
            d[(i, i)] = *v;
        }
        let l = d.lambda_max_power(200);
        assert!((l - 3.0).abs() < 1e-9, "{l}");
    }

    #[test]
    fn vector_kernels() {
        let a = [1.0, 2.0, 2.0];
        assert_eq!(dot(&a, &a), 9.0);
        assert_eq!(norm2(&a), 3.0);
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 5.0]);
        assert_eq!(dist2_sq(&a, &[0.0, 0.0, 0.0]), 9.0);
    }
}
