//! Entropic semi-discrete OT dual oracle — backend seam over the
//! shared numeric core in [`crate::kernel`].
//!
//! Mirrors the L1 Pallas kernel / L2 model exactly (see
//! `python/compile/kernels/ref.py` for the math): given the local
//! potential `η̄`, a batch of cost rows `C[r,·]`, and `β`,
//!
//!   grad = mean_r softmax((η̄ − C_r)/β)          (paper Lemma 1 Eq. 6)
//!   val  = mean_r β·logsumexp((η̄ − C_r)/β)      (dual objective part)
//!
//! The arithmetic lives in [`crate::kernel::dual_oracle`], which
//! consumes cost rows through the zero-copy
//! [`CostRowSource`](crate::kernel::CostRowSource) seam; this module
//! keeps the backend contract:
//!
//! Two interchangeable backends implement [`DualOracle`]:
//! * [`NativeOracle`] — the kernel path; f64; zero FFI overhead, zero
//!   per-activation cost-row copies.
//! * [`crate::runtime::PjrtOracle`] — executes the AOT JAX/Pallas
//!   artifact through PJRT, proving the three-layer path (materializes
//!   rows into its FFI staging buffer — inherent to the boundary).
//! Integration tests pin them together (`rust/tests/pjrt_parity.rs`).

pub mod sinkhorn;

use crate::kernel::{self, CostRowSource};
use crate::measures::CostRows;

pub use crate::kernel::OracleScratch;

/// Compute the oracle over a materialized buffer into preallocated
/// outputs — thin wrapper over [`kernel::dual_oracle`], kept for
/// benches/tests that hold a [`CostRows`].
///
/// `grad` (len n) receives the mean softmax; returns the mean
/// `β·logsumexp` value.
pub fn dual_oracle_into(
    eta: &[f64],
    cost: &CostRows,
    beta: f64,
    grad: &mut [f64],
    scratch: &mut OracleScratch,
) -> f64 {
    kernel::dual_oracle(eta, cost, beta, grad, scratch)
}

/// Allocating convenience wrapper.
pub fn dual_oracle(eta: &[f64], cost: &CostRows, beta: f64) -> (Vec<f64>, f64) {
    let mut grad = vec![0.0; cost.n];
    let mut scratch = OracleScratch::default();
    let val = dual_oracle_into(eta, cost, beta, &mut grad, &mut scratch);
    (grad, val)
}

/// The oracle contract used by every algorithm and the coordinator.
///
/// Cost rows arrive through the zero-copy
/// [`CostRowSource`](crate::kernel::CostRowSource) seam — a
/// [`crate::measures::MeasureRows`] binding on the hot path, or a
/// materialized [`CostRows`] buffer (which implements the same trait)
/// in benches and tests.
///
/// Not `Send`: the PJRT backend wraps thread-affine FFI handles and the
/// coordinator's event loop is single-threaded by design (determinism).
pub trait DualOracle {
    /// Fill `grad` with `∇̃W*_{β,μ}(η̄)` and return the dual value part.
    fn eval(
        &mut self,
        eta: &[f64],
        cost: &dyn CostRowSource,
        beta: f64,
        grad: &mut [f64],
    ) -> f64;

    fn name(&self) -> &'static str;

    /// Route per-pass telemetry (oracle passes, borrowed/generated cost
    /// rows) into `obs`. Default: ignore — backends without kernel-side
    /// counting (e.g. PJRT) simply don't report these counters.
    fn attach_obs(&mut self, _obs: std::sync::Arc<crate::obs::Telemetry>) {}

    /// Select the lane width of the row kernels
    /// ([`KernelImpl`](crate::kernel::KernelImpl)). Default: ignore —
    /// backends that don't run the native kernels (e.g. PJRT executes
    /// the AOT artifact) have no lane-width knob.
    fn set_kernel(&mut self, _kernel: crate::kernel::KernelImpl) {}

    /// Evaluate B independent η̄ blocks (`etas`/`grads` are B row-major
    /// blocks of n; `vals` has len B) against one cost source.
    ///
    /// The default is the literal sequential loop — the bitwise
    /// baseline any batched override must reproduce under the scalar
    /// kernel. [`NativeOracle`] overrides it with the cache-blocked
    /// [`kernel::dual_oracle_batch`] single pass.
    fn eval_batch(
        &mut self,
        etas: &[f64],
        cost: &dyn CostRowSource,
        beta: f64,
        grads: &mut [f64],
        vals: &mut [f64],
    ) {
        let n = cost.n();
        let b = vals.len();
        assert_eq!(etas.len(), b * n);
        assert_eq!(grads.len(), b * n);
        for bi in 0..b {
            vals[bi] = self.eval(
                &etas[bi * n..(bi + 1) * n],
                cost,
                beta,
                &mut grads[bi * n..(bi + 1) * n],
            );
        }
    }
}

/// f64 native backend — the kernel, directly.
#[derive(Default)]
pub struct NativeOracle {
    scratch: OracleScratch,
}

impl DualOracle for NativeOracle {
    fn eval(
        &mut self,
        eta: &[f64],
        cost: &dyn CostRowSource,
        beta: f64,
        grad: &mut [f64],
    ) -> f64 {
        kernel::dual_oracle(eta, cost, beta, grad, &mut self.scratch)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn attach_obs(&mut self, obs: std::sync::Arc<crate::obs::Telemetry>) {
        self.scratch.attach_obs(obs);
    }

    fn set_kernel(&mut self, kernel: crate::kernel::KernelImpl) {
        self.scratch.set_kernel(kernel);
    }

    fn eval_batch(
        &mut self,
        etas: &[f64],
        cost: &dyn CostRowSource,
        beta: f64,
        grads: &mut [f64],
        vals: &mut [f64],
    ) {
        kernel::dual_oracle_batch(etas, cost, beta, grads, vals, &mut self.scratch);
    }
}

/// Config-level backend selector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OracleBackendSpec {
    Native,
    /// PJRT execution of `artifacts/oracle_m{M}_n{n}.hlo.txt`.
    Pjrt { artifacts_dir: String },
}

impl OracleBackendSpec {
    pub fn build(&self, m: usize, n: usize) -> Result<Box<dyn DualOracle>, String> {
        match self {
            OracleBackendSpec::Native => Ok(Box::new(NativeOracle::default())),
            OracleBackendSpec::Pjrt { artifacts_dir } => Ok(Box::new(
                crate::runtime::PjrtOracle::load(artifacts_dir, m, n)?,
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng64;

    fn random_case(seed: u64, m: usize, n: usize) -> (Vec<f64>, CostRows) {
        let mut rng = Rng64::new(seed);
        let eta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut cost = CostRows::new(m, n);
        for v in cost.data.iter_mut() {
            *v = rng.uniform_in(0.0, 4.0);
        }
        (eta, cost)
    }

    #[test]
    fn grad_is_probability_distribution() {
        let (eta, cost) = random_case(1, 16, 50);
        let (g, _) = dual_oracle(&eta, &cost, 0.1);
        assert!(g.iter().all(|&x| x >= 0.0));
        assert!((g.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_sample_sharp_beta_is_argmax() {
        let (eta, cost) = random_case(2, 1, 20);
        let (g, _) = dual_oracle(&eta, &cost, 1e-9);
        let best = (0..20)
            .max_by(|&a, &b| {
                (eta[a] - cost.row(0)[a])
                    .partial_cmp(&(eta[b] - cost.row(0)[b]))
                    .unwrap()
            })
            .unwrap();
        assert!((g[best] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn value_matches_naive_f64() {
        let (eta, cost) = random_case(3, 8, 12);
        let beta = 0.37;
        let (_, val) = dual_oracle(&eta, &cost, beta);
        // naive unstable computation in f64 is fine at this scale
        let mut want = 0.0;
        for r in 0..8 {
            let z: f64 = (0..12)
                .map(|l| ((eta[l] - cost.row(r)[l]) / beta).exp())
                .sum();
            want += beta * z.ln();
        }
        want /= 8.0;
        assert!((val - want).abs() < 1e-9, "{val} vs {want}");
    }

    #[test]
    fn grad_is_derivative_of_value() {
        let (eta, cost) = random_case(4, 6, 9);
        let beta = 0.5;
        let (g, _) = dual_oracle(&eta, &cost, beta);
        let eps = 1e-6;
        for l in 0..9 {
            let mut ep = eta.clone();
            ep[l] += eps;
            let (_, vp) = dual_oracle(&ep, &cost, beta);
            ep[l] -= 2.0 * eps;
            let (_, vm) = dual_oracle(&ep, &cost, beta);
            let fd = (vp - vm) / (2.0 * eps);
            assert!((g[l] - fd).abs() < 1e-5, "block {l}: {} vs {fd}", g[l]);
        }
    }

    #[test]
    fn no_overflow_at_extreme_logits() {
        let n = 10;
        let mut eta = vec![0.0; n];
        eta[3] = 1e4;
        let mut cost = CostRows::new(2, n);
        cost.data.iter_mut().for_each(|v| *v = 1.0);
        let (g, val) = dual_oracle(&eta, &cost, 1e-3);
        assert!(g.iter().all(|x| x.is_finite()));
        assert!(val.is_finite());
        assert!((g[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn into_variant_reuses_buffers() {
        let (eta, cost) = random_case(5, 4, 7);
        let mut grad = vec![0.0; 7];
        let mut scratch = OracleScratch::default();
        let v1 = dual_oracle_into(&eta, &cost, 0.2, &mut grad, &mut scratch);
        let (g2, v2) = dual_oracle(&eta, &cost, 0.2);
        assert_eq!(grad, g2);
        assert_eq!(v1, v2);
    }
}
