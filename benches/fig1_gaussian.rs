//! Figure 1 — Gaussian barycenter: dual objective + consensus distance
//! vs virtual time, 3 algorithms × 4 topologies (complete, Erdős–Rényi,
//! cycle, star).
//!
//! Writes `results/fig1_<topology>.csv` with one column pair per
//! algorithm and prints REPORT lines. Default scale is CI-sized
//! (m = 50, T = 30 s); set `A2DWB_FULL=1` for the paper's m = 500,
//! T = 200 s.

use a2dwb::graph::TopologySpec;
use a2dwb::metrics::{write_csv, Series};
use a2dwb::prelude::*;

fn main() {
    let full = std::env::var("A2DWB_FULL").is_ok();
    let (nodes, duration) = if full { (500, 200.0) } else { (50, 30.0) };
    let seed = 42;

    println!("== Fig. 1: Gaussian barycenter (m={nodes}, T={duration}s) ==");
    let topologies: [(&str, TopologySpec); 4] = [
        ("complete", TopologySpec::Complete),
        ("erdos-renyi", TopologySpec::ErdosRenyi { p: if full { 0.02 } else { 0.1 }, seed }),
        ("cycle", TopologySpec::Cycle),
        ("star", TopologySpec::Star),
    ];

    for (label, topo) in topologies {
        let mut series: Vec<Series> = Vec::new();
        let mut finals = Vec::new();
        for alg in AlgorithmKind::all() {
            let r = ExperimentBuilder::gaussian()
                .nodes(nodes)
                .topology(topo)
                .algorithm(alg)
                .duration(duration)
                .seed(seed)
                .build()
                .expect("valid experiment")
                .run()
                .expect("run");
            println!("{}", r.summary());
            let mut dual = r.dual_objective.clone();
            dual.name = format!("dual_{}", alg.name());
            let mut cons = r.consensus.clone();
            cons.name = format!("consensus_{}", alg.name());
            series.push(dual);
            series.push(cons);
            finals.push((alg.name(), r.final_dual_objective(), r.final_consensus()));
        }
        let refs: Vec<&Series> = series.iter().collect();
        let path = format!("results/fig1_{label}.csv");
        write_csv(&path, &refs).expect("csv");
        println!("wrote {path}");
        // the Fig.-1 shape: a2dwb lowest dual AND lowest consensus
        let a = finals.iter().find(|f| f.0 == "a2dwb").unwrap();
        let best_other_dual = finals
            .iter()
            .filter(|f| f.0 != "a2dwb")
            .map(|f| f.1)
            .fold(f64::INFINITY, f64::min);
        // near-ties (within 0.1% of total progress) are statistically
        // indistinguishable at CI scale — label them TIE, not LOSS
        let progress = series[0].first_value().unwrap() - a.1;
        let verdict = if a.1 <= best_other_dual + 1e-9 {
            "WIN"
        } else if a.1 <= best_other_dual + 1e-3 * progress.abs() {
            "TIE"
        } else {
            "LOSS"
        };
        println!(
            "FIG1 {label}: a2dwb dual={:.6} best-other={:.6} -> {verdict}",
            a.1, best_other_dual
        );
        println!();
    }
}
