//! # A²DWB — Asynchronous Decentralized Wasserstein Barycenter
//!
//! Production-grade reproduction of *“An Asynchronous Decentralized
//! Algorithm for Wasserstein Barycenter Problem”* (Zhang, Qian, Xie, 2023).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — a Pallas kernel (`python/compile/kernels/otgrad.py`) computing
//!   the stochastic entropic-dual oracle (row-softmax mean + batch LSE).
//! * **L2** — a JAX model (`python/compile/model.py`) wrapping the kernel,
//!   AOT-lowered to HLO text artifacts by `python/compile/aot.py`.
//! * **L3** — this crate: the asynchronous decentralized runtime (the
//!   paper's contribution), a discrete-event network simulator, the three
//!   algorithms (A²DWB / A²DWBN / DCWB), the generic inducing methods
//!   (ASBCDS / PASBCDS), and every substrate they need (PRNG, linear
//!   algebra incl. a Jacobi eigensolver, graph topologies, semi-discrete
//!   measures, metrics, CLI, bench harness) built from scratch.
//!
//! Python never runs on the request path: the Rust runtime executes the
//! AOT artifacts through PJRT (`runtime`), or uses a bit-faithful native
//! oracle (`ot`) cross-validated against them.
//!
//! ## Quick start
//!
//! ```no_run
//! use a2dwb::prelude::*;
//!
//! let cfg = ExperimentConfig {
//!     nodes: 20,
//!     topology: TopologySpec::Cycle,
//!     algorithm: AlgorithmKind::A2dwb,
//!     ..ExperimentConfig::gaussian_default()
//! };
//! let report = run_experiment(&cfg).unwrap();
//! println!("final dual objective: {}", report.final_dual_objective());
//! ```

pub mod algo;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod graph;
pub mod linalg;
pub mod measures;
pub mod metrics;
pub mod ot;
pub mod problems;
pub mod proptest_util;
pub mod rng;
pub mod runtime;
pub mod sim;

/// One-stop imports for examples and binaries.
pub mod prelude {
    pub use crate::algo::{AlgorithmKind, ThetaSeq};
    pub use crate::coordinator::{
        run_experiment, ExperimentConfig, ExperimentReport, FaultModel, TaskSpec,
    };
    pub use crate::graph::{Graph, TopologySpec};
    pub use crate::measures::MeasureSpec;
    pub use crate::metrics::Series;
    pub use crate::ot::OracleBackendSpec;
    pub use crate::rng::Rng64;
}
