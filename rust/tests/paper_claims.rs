//! Scaled-down versions of the paper's §4 empirical claims, run as
//! integration tests: the *shape* of Figures 1–2 (who wins, and how
//! topology ordering behaves) must hold at CI scale.

use a2dwb::prelude::*;

fn base(nodes: usize, duration: f64) -> ExperimentConfig {
    ExperimentConfig {
        nodes,
        duration,
        samples_per_activation: 16,
        eval_samples: 32,
        metric_interval: 1.0,
        ..ExperimentConfig::gaussian_default()
    }
}

#[test]
fn fig1_a2dwb_beats_dcwb_on_every_topology() {
    for topo in [
        TopologySpec::Complete,
        TopologySpec::ErdosRenyi { p: 0.25, seed: 42 },
        TopologySpec::Cycle,
        TopologySpec::Star,
    ] {
        let mut cfg = base(16, 12.0);
        cfg.topology = topo;
        cfg.algorithm = AlgorithmKind::A2dwb;
        let a = run_experiment(&cfg).unwrap();
        cfg.algorithm = AlgorithmKind::Dcwb;
        let s = run_experiment(&cfg).unwrap();
        assert!(
            a.final_dual_objective() <= s.final_dual_objective() + 1e-9,
            "{}: a2dwb {} !<= dcwb {}",
            topo.name(),
            a.final_dual_objective(),
            s.final_dual_objective()
        );
    }
}

#[test]
fn fig1_compensation_does_not_hurt() {
    // A²DWB (compensated) vs A²DWBN (naive): the paper reports the
    // compensated variant ahead. At CI scale the θ-lag between the two
    // evaluation points is small, so we assert the compensated variant
    // is at worst within 2% of the naive one's *progress* (the full
    // comparison under growing staleness is benches/ablate_compensation).
    let mut cfg = base(16, 12.0);
    cfg.topology = TopologySpec::Cycle;
    cfg.algorithm = AlgorithmKind::A2dwb;
    let a = run_experiment(&cfg).unwrap();
    cfg.algorithm = AlgorithmKind::A2dwbn;
    let naive = run_experiment(&cfg).unwrap();
    let progress = naive.dual_objective.first_value().unwrap()
        - naive.final_dual_objective();
    assert!(progress > 0.0, "naive made no progress");
    assert!(
        a.final_dual_objective() <= naive.final_dual_objective() + 0.02 * progress,
        "compensated {} vs naive {} (progress {progress})",
        a.final_dual_objective(),
        naive.final_dual_objective()
    );
}

#[test]
fn fig1_connectivity_ordering() {
    // convergence degrades as connectivity shrinks: complete reaches a
    // lower dual value than cycle and star at the same budget.
    let mut vals = Vec::new();
    for topo in [TopologySpec::Complete, TopologySpec::Cycle, TopologySpec::Star] {
        let mut cfg = base(16, 12.0);
        cfg.topology = topo;
        let r = run_experiment(&cfg).unwrap();
        // normalize by the starting value so topologies are comparable
        let first = r.dual_objective.first_value().unwrap();
        let last = r.final_dual_objective();
        vals.push((topo.name(), first - last)); // progress made
    }
    assert!(
        vals[0].1 >= vals[1].1 * 0.9,
        "complete should beat cycle: {vals:?}"
    );
    assert!(
        vals[0].1 >= vals[2].1 * 0.9,
        "complete should beat star: {vals:?}"
    );
}

#[test]
fn fig2_digits_pipeline_runs() {
    // the MNIST-task pipeline end-to-end at tiny scale
    let mut cfg = base(8, 6.0);
    cfg.measure = MeasureSpec::Digits { digit: 3, side: 14, idx_path: None };
    let r = run_experiment(&cfg).unwrap();
    let first = r.dual_objective.first_value().unwrap();
    let last = r.final_dual_objective();
    assert!(last < first, "digit run made no progress: {first} → {last}");
    // barycenter is a distribution over the 14×14 grid
    assert_eq!(r.barycenter.len(), 196);
    assert!((r.barycenter.iter().sum::<f64>() - 1.0).abs() < 1e-6);
}

#[test]
fn async_does_more_work_per_virtual_second() {
    // mechanism check: in the same virtual budget, the async runtime
    // performs ~duration/interval·m activations while DCWB completes
    // only ~duration/max-delay rounds.
    let mut cfg = base(12, 10.0);
    cfg.algorithm = AlgorithmKind::A2dwb;
    let a = run_experiment(&cfg).unwrap();
    cfg.algorithm = AlgorithmKind::Dcwb;
    let s = run_experiment(&cfg).unwrap();
    let expected_activations = (10.0 / 0.2) * 12.0;
    assert!(
        (a.activations as f64) > 0.8 * expected_activations,
        "async activations {} vs expected {expected_activations}",
        a.activations
    );
    assert!(
        s.rounds as f64 <= 10.0 / 0.6, // mean max-edge delay ≥ 0.6
        "sync rounds {} look too many",
        s.rounds
    );
}

#[test]
fn messages_scale_with_topology_density() {
    let mut cfg = base(16, 6.0);
    cfg.topology = TopologySpec::Complete;
    let dense = run_experiment(&cfg).unwrap();
    cfg.topology = TopologySpec::Cycle;
    let sparse = run_experiment(&cfg).unwrap();
    assert!(
        dense.messages > sparse.messages * 3,
        "complete {} vs cycle {}",
        dense.messages,
        sparse.messages
    );
}

#[test]
fn stragglers_hurt_sync_more_than_async() {
    use a2dwb::coordinator::FaultModel;
    // 10% of nodes slowed 10x: the sync barrier inherits it every
    // round; the async runtime only sees staler gradients.
    let fault = FaultModel {
        straggler_fraction: 0.1,
        straggler_slowdown: 10.0,
        drop_prob: 0.0,
    };
    let mut cfg = base(16, 12.0);
    cfg.faults = fault.clone();
    cfg.algorithm = AlgorithmKind::A2dwb;
    let a_slow = run_experiment(&cfg).unwrap();
    cfg.algorithm = AlgorithmKind::Dcwb;
    let s_slow = run_experiment(&cfg).unwrap();
    // clean runs for reference
    let mut clean = base(16, 12.0);
    clean.algorithm = AlgorithmKind::Dcwb;
    let s_clean = run_experiment(&clean).unwrap();
    // sync round count collapses under stragglers...
    assert!(
        s_slow.rounds * 3 <= s_clean.rounds,
        "sync rounds should collapse: {} vs clean {}",
        s_slow.rounds,
        s_clean.rounds
    );
    // ...while async keeps its cadence and stays ahead on the dual
    assert!(
        a_slow.final_dual_objective() < s_slow.final_dual_objective(),
        "async {} vs sync {} under stragglers",
        a_slow.final_dual_objective(),
        s_slow.final_dual_objective()
    );
}

#[test]
fn packet_loss_degrades_gracefully() {
    use a2dwb::coordinator::FaultModel;
    let mut cfg = base(16, 12.0);
    cfg.faults = FaultModel {
        straggler_fraction: 0.0,
        straggler_slowdown: 1.0,
        drop_prob: 0.3,
    };
    let lossy = run_experiment(&cfg).unwrap();
    cfg.faults = FaultModel::default();
    let clean = run_experiment(&cfg).unwrap();
    // still converging (finite + made progress), just slower
    assert!(lossy.final_dual_objective().is_finite());
    let p_clean = clean.dual_objective.first_value().unwrap()
        - clean.final_dual_objective();
    let p_lossy = lossy.dual_objective.first_value().unwrap()
        - lossy.final_dual_objective();
    assert!(p_lossy > 0.25 * p_clean, "lossy progress collapsed: {p_lossy} vs {p_clean}");
}

#[test]
fn quantized_wire_with_error_feedback_tracks_the_dense_trajectory() {
    use a2dwb::exec::net::{self, MeshOpts, Pacing};
    // The error-feedback claim (arXiv:2010.14325) transplanted to the
    // mesh wire: block-quantized gradients with the residual folded
    // into the next send converge like the dense wire — tight at
    // 8 bits, looser at 4 — while the *naive* 4-bit quantizer (same
    // bits, residual dropped) is strictly worse than its compensated
    // twin. Lockstep pacing makes all four runs deterministic and
    // schedule-identical, so the dual gaps isolate the wire format.
    let mut cfg = base(8, 6.0);
    cfg.topology = TopologySpec::Complete; // maximize cross-shard (quantized) edges
    cfg.algorithm = AlgorithmKind::A2dwb;
    let run = |compression: Compression| {
        let cfg = ExperimentConfig { compression, ..cfg.clone() };
        net::run_mesh_threads(&cfg, &MeshOpts::new(2).pacing(Pacing::Lockstep))
            .expect("quantized lockstep mesh")
    };

    let dense = run(Compression::off());
    let d0 = dense.final_dual_objective();
    let progress = dense.dual_objective.first_value().unwrap() - d0;
    assert!(progress > 0.0, "dense run made no progress");

    let ef8 = run(Compression::quantized(8)).final_dual_objective();
    let ef4 = run(Compression::quantized(4)).final_dual_objective();
    let naive4 =
        run(Compression { bits: 4, error_feedback: false }).final_dual_objective();

    assert!(
        (ef8 - d0).abs() <= 0.05 * progress,
        "8-bit EF drifted from dense: {ef8} vs {d0} (progress {progress})"
    );
    assert!(
        (ef4 - d0).abs() <= 0.25 * progress,
        "4-bit EF drifted from dense: {ef4} vs {d0} (progress {progress})"
    );
    assert!(
        naive4 > ef4,
        "dropping the residual must hurt at 4 bits: naive {naive4} !> compensated {ef4}"
    );
}

#[test]
fn fault_model_validation() {
    use a2dwb::coordinator::FaultModel;
    let mut cfg = base(8, 2.0);
    cfg.faults = FaultModel {
        straggler_fraction: 1.5,
        straggler_slowdown: 2.0,
        drop_prob: 0.0,
    };
    assert!(run_experiment(&cfg).is_err());
    cfg.faults = FaultModel {
        straggler_fraction: 0.1,
        straggler_slowdown: 0.5,
        drop_prob: 0.0,
    };
    assert!(run_experiment(&cfg).is_err());
}
