"""L2 model tests: signatures, batching, and primal readback."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model


def _mk(seed, nodes, m, n):
    rng = np.random.default_rng(seed)
    etas = jnp.array(rng.normal(size=(nodes, n)), jnp.float32)
    costs = jnp.array(rng.uniform(0, 9, size=(nodes, m, n)), jnp.float32)
    return etas, costs, jnp.array([0.25], jnp.float32)


def test_node_oracle_shapes():
    etas, costs, beta = _mk(0, 1, 16, 48)
    g, v = model.node_oracle(etas[0], costs[0], beta)
    assert g.shape == (48,)
    assert v.shape == (1,)


def test_node_oracle_matches_ref_twin():
    etas, costs, beta = _mk(1, 1, 16, 48)
    g1, v1 = model.node_oracle(etas[0], costs[0], beta)
    g2, v2 = model.node_oracle_ref(etas[0], costs[0], beta)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-6)


def test_multi_node_oracle_equals_loop():
    etas, costs, beta = _mk(2, 5, 8, 20)
    gs, vs = model.multi_node_oracle(etas, costs, beta)
    assert gs.shape == (5, 20) and vs.shape == (5, 1)
    for i in range(5):
        g, v = model.node_oracle_ref(etas[i], costs[i], beta)
        np.testing.assert_allclose(gs[i], g, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(vs[i], v, rtol=1e-5, atol=1e-6)


def test_barycenter_weights_simplex():
    etas, costs, beta = _mk(3, 1, 32, 64)
    w = model.barycenter_weights(etas[0], costs[0], beta)
    assert float(jnp.min(w)) >= 0
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, rtol=1e-5)


def test_beta_sharpens_softmax():
    """Smaller beta concentrates mass on the argmin-cost support point."""
    rng = np.random.default_rng(4)
    n = 30
    eta = jnp.zeros((n,), jnp.float32)
    cost = jnp.array(rng.uniform(1, 9, size=(1, n)), jnp.float32)
    g_sharp, _ = model.node_oracle_ref(eta, cost, jnp.array([1e-3], jnp.float32))
    g_soft, _ = model.node_oracle_ref(eta, cost, jnp.array([1000.0], jnp.float32))
    assert float(jnp.max(g_sharp)) > 0.99  # near one-hot at argmin cost
    assert int(jnp.argmax(g_sharp)) == int(jnp.argmin(cost[0]))
    np.testing.assert_allclose(
        np.asarray(g_soft), np.full(n, 1.0 / n), atol=1e-3
    )  # near uniform
